//! Pure-Rust GCN execution engine — the default [`Backend`], running on
//! the sparse block-diagonal [`PackedBatch`] layout.
//!
//! Implements the paper's model (Fig 7) with the exact artifact semantics
//! of `python/compile/aot.py` / `python/compile/model.py`:
//!
//! * forward: Fig 5 dual feature embedding → `n_conv` graph convolutions
//!   (Kipf–Welling aggregate-update `A' · (E · W) + b`, per-node channel
//!   normalization, ReLU) → segment-sum readout per conv level →
//!   linear head predicting log-runtime `z` (one value per graph);
//! * train: the §III-C weighted relative-error loss
//!   `ξ = |exp(z − log ȳ) − 1|` (linearized beyond `|d| = 3`), analytic
//!   backprop through the whole network, and an Adagrad step with weight
//!   decay — semantically identical to `model.train_step`.
//!
//! The compute core is organized for the serving layer's traffic
//! (PR 5 — see DESIGN.md §"Native engine: workspace & kernels"):
//!
//! * **Workspace memory.** Every buffer the engine touches comes from a
//!   recycled [`Workspace`] arena (a backend-owned pool at the public
//!   entry points — warm even for the short-lived scoped workers of a
//!   `predict_runtimes` fan-out — caller-held for tests/benches).
//!   Parallel row fills write blocks *directly* into one preallocated
//!   output via [`crate::util::threadpool::split_rows`] — no per-block
//!   staging `Vec`s, no join-time re-copy — so repeated
//!   `infer`/`train_step` calls do no steady-state node-matrix
//!   allocation at all (pinned by the allocation-budget test below).
//! * **Inference fast path.** [`Backend::infer`] never materializes the
//!   training `Forward` stash: it ping-pongs two node matrices
//!   (activations and the `E·W` projection), fuses the CSR gather with
//!   bias/norm/ReLU per row, and folds the segment-sum readout
//!   incrementally per conv level. `PredictService`, `predict_runtimes`
//!   and the `PredictorCost` search bridge all reach inference through
//!   this path. The fast path and the training forward share the
//!   `runtime::kernels` microkernels and the same per-accumulator
//!   summation chains, so their outputs are bit-identical (pinned
//!   against the zoo, incl. the 59-stage `resnet50`).
//! * **Tiled kernels + parallel backward.** The embedding/conv GEMMs run
//!   as register-tiled panels over `chunks_exact` (f64 accumulation in
//!   the pre-tiled chain order, so the JAX parity fixtures still pass at
//!   ≤1e-5), and `backward` fans out over *graph-aligned* row blocks
//!   ([`PackedBatch::graph_blocks`]): the block-diagonal adjacency keeps
//!   every block self-contained, each worker accumulates private
//!   gradients, and the per-block results are reduced in fixed block
//!   order — bitwise-deterministic for any thread count.
//!
//! Tensor math accumulates in `f64` and stores `f32` at the same op
//! boundaries as the JAX model; because CSR rows keep ascending column
//! order, every per-element accumulation visits the same nonzero terms in
//! the same order as the dense in-order sweep, so outputs match the
//! dependency-free reference (`python/compile/kernels/ref.py`) to ≤1e-5.
//! The parity tests below pin that against JAX-generated reference
//! numbers via `PackedBatch::from_dense` over the dense fixtures.
//!
//! [`Backend::predict_runtimes`] is overridden to fan batch chunks out
//! over the thread pool, balancing chunks by total packed *nodes* (not
//! graph count) so one giant graph cannot straggle behind a queue of
//! tiny ones.

use crate::constants::{
    ADAGRAD_EPS, BATCH, DEP_DIM, EMB_DEP, EMB_INV, INV_DIM, NODE_DIM, N_CONV,
};
use crate::dataset::sample::GraphSample;
use crate::features::normalize::FeatureStats;
use crate::model::{Csr, PackedBatch};
use crate::runtime::backend::{predict_chunk, Backend};
use crate::runtime::kernels;
use crate::runtime::kernels_simd::{self, KernelVariant};
use crate::runtime::manifest::Manifest;
use crate::runtime::params::Params;
use crate::runtime::quant::QuantParams;
use crate::runtime::workspace::{Workspace, WorkspaceStats};
use crate::util::threadpool::{
    chunk_ranges, num_threads, parallel_map, parallel_map_vec, parallel_map_vec_threads,
    split_rows,
};
use anyhow::{ensure, Result};
use std::ops::Range;
use std::sync::Mutex;

// The conv math below indexes weight tensors of manifest shape
// [HIDDEN, HIDDEN] with NODE_DIM strides; that is only sound while the
// conv width equals the node embedding width (true in the paper's model).
const _: () = assert!(
    crate::constants::HIDDEN == NODE_DIM,
    "native backend assumes HIDDEN == NODE_DIM (conv width == embedding width)"
);

/// Channel-normalization epsilon (`graph_batch_norm` in `model.py`).
pub(crate) const LN_EPS: f64 = 1e-5;
/// Loss linearization point: ξ switches to a linear tail beyond |d| = 3.
pub(crate) const LOSS_CLIP: f64 = 3.0;

/// Minimum packed rows per parallel block. Below roughly one chunk of
/// small graphs the scoped fan-out costs more than it saves — and the
/// chunked [`Backend::predict_runtimes`] path is already parallel at the
/// batch level, so in-batch blocking only needs to win on big graphs.
const PAR_MIN_ROWS: usize = 512;

/// Node budget per graph-aligned backward block. Fixed — never derived
/// from the thread count — so the block partition, and therefore the
/// order in which per-block gradient accumulators are reduced, depends
/// only on the batch: parallel backward is bitwise-deterministic across
/// thread counts. Aliased to [`crate::constants::PARTITION_BLOCK_NODES`]
/// so `model::partition`'s cut points are always backward-block
/// boundaries — the partitioned train path tiles exactly like the
/// corresponding rows of the full graph would.
const BACKWARD_BLOCK_NODES: usize = crate::constants::PARTITION_BLOCK_NODES;

/// Fill a row-major `[n_rows, width]` f32 matrix in place, parallel over
/// contiguous row blocks on the shared thread pool when the batch is
/// large. Workers write their block directly into `out` (disjoint
/// sub-slices via [`split_rows`]) — no per-block staging buffer, no
/// re-copy. Deterministic: each row depends only on its own index.
fn par_rows_into<F>(n_rows: usize, width: usize, out: &mut [f32], f: F)
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    debug_assert_eq!(out.len(), n_rows * width);
    let serial = |out: &mut [f32]| {
        for (r, row) in out.chunks_mut(width.max(1)).enumerate() {
            f(r, row);
        }
    };
    if n_rows <= PAR_MIN_ROWS {
        // below the fan-out threshold: no range bookkeeping, no allocs
        serial(out);
        return;
    }
    let ranges = chunk_ranges(n_rows, PAR_MIN_ROWS);
    if ranges.len() <= 1 {
        serial(out);
        return;
    }
    let blocks: Vec<(Range<usize>, &mut [f32])> =
        ranges.iter().cloned().zip(split_rows(out, &ranges, width)).collect();
    parallel_map_vec(blocks, |(range, block)| {
        for (i, row) in block.chunks_mut(width.max(1)).enumerate() {
            f(range.start + i, row);
        }
    });
}

/// Contiguous sample chunks balanced by total packed **nodes**. A
/// 59-stage `resnet50` schedule is an order of magnitude more work than
/// a generator pipeline, so fixed graph-count chunks leave whichever
/// worker draws the big graphs straggling; node-budget chunks equalize
/// work instead. Several chunks per worker are produced so the
/// claim-one-at-a-time scheduler can smooth the residual imbalance.
/// Predictions are chunk-invariant (the packed layout is
/// block-diagonal), so this is purely a scheduling policy.
///
/// [`balanced_chunks_with`] takes the workspace node budget explicitly;
/// the per-chunk graph cap is derived from it (the historical hard
/// [`BATCH`] cap survives as its ceiling, so zoo-scale corpora chunk
/// exactly as before) and no multi-graph chunk exceeds the budget in
/// packed nodes — the knob that bounds per-worker workspace memory on
/// TpuGraphs-scale inputs.
pub(crate) fn balanced_chunks<'s, 'a>(
    samples: &'s [&'a GraphSample],
    workers: usize,
) -> Vec<&'s [&'a GraphSample]> {
    balanced_chunks_with(samples, workers, crate::constants::node_budget())
}

/// See [`balanced_chunks`].
pub(crate) fn balanced_chunks_with<'s, 'a>(
    samples: &'s [&'a GraphSample],
    workers: usize,
    node_budget: usize,
) -> Vec<&'s [&'a GraphSample]> {
    if samples.is_empty() {
        return Vec::new();
    }
    let node_budget = node_budget.max(1);
    let total_nodes: usize = samples.iter().map(|s| s.n_stages as usize).sum();
    let want = (workers.max(1) * 4).max(1);
    // balance across workers, but never let one chunk's packed nodes
    // (≈ its workspace size) exceed the node budget
    let budget = total_nodes.div_ceil(want).max(1).min(node_budget);
    // graph cap auto-derived from the budget: enough mean-sized graphs
    // to fill it, floored at 1 and capped at the historical BATCH
    let mean = (total_nodes / samples.len()).max(1);
    let graph_cap = (node_budget / mean).clamp(1, BATCH);
    let mut chunks = Vec::new();
    let (mut start, mut acc) = (0usize, 0usize);
    for (i, s) in samples.iter().enumerate() {
        let n = (s.n_stages as usize).max(1);
        if i > start && (acc + n > budget || i - start >= graph_cap) {
            chunks.push(&samples[start..i]);
            start = i;
            acc = 0;
        }
        acc += n;
    }
    chunks.push(&samples[start..]);
    chunks
}

/// Upper bound on idle pooled workspaces per backend. Each concurrent
/// caller holds at most one; anything beyond the fan-out width is idle
/// memory.
const WS_POOL_CAP: usize = 32;

/// The native engine: its manifest plus a pool of warm [`Workspace`]
/// arenas. Model state is immutable, so inference parallelizes freely;
/// the pool is the one synchronized bit (lock held only to pop/push).
pub struct NativeBackend {
    manifest: Manifest,
    /// Warm buffer arenas shared across *calling threads*. A
    /// thread-local arena would start cold on every `predict_runtimes`
    /// fan-out (the thread pool spawns fresh scoped workers per call),
    /// re-paying all node-matrix allocations per chunk; a backend-owned
    /// pool keeps buffers warm no matter which thread runs the kernels.
    ws_pool: Mutex<Vec<Workspace>>,
    /// Microkernel tier the inference fast path dispatches through.
    /// `Scalar` (the default for every pre-existing constructor) keeps
    /// the fast path bitwise-identical to the training forward; SIMD
    /// tiers are a declared numeric mode within `SIMD_REL_TOL` — see
    /// `runtime::kernels_simd`. Training and `infer_full` always run the
    /// scalar kernels regardless of this field.
    variant: KernelVariant,
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

impl NativeBackend {
    /// The paper's configuration: two graph-convolution layers.
    pub fn new() -> NativeBackend {
        NativeBackend::with_layers(N_CONV)
    }

    /// A conv-depth ablation variant (§III-C sweep: 0/1/2/4 layers).
    pub fn with_layers(n_conv: usize) -> NativeBackend {
        NativeBackend {
            manifest: Manifest::native(n_conv),
            ws_pool: Mutex::new(Vec::new()),
            variant: KernelVariant::Scalar,
        }
    }

    /// The paper's configuration with an explicit microkernel tier. The
    /// request is clamped to what this build and CPU can actually run
    /// ([`kernels_simd::resolve`] against [`kernels_simd::detected`]), so
    /// asking for AVX2 on a non-AVX2 host — or in a build without the
    /// `simd` cargo feature — cleanly falls back instead of faulting.
    pub fn with_variant(variant: KernelVariant) -> NativeBackend {
        NativeBackend::with_layers_variant(N_CONV, variant)
    }

    /// Conv-depth variant with an explicit microkernel tier (clamped the
    /// same way as [`Self::with_variant`]).
    pub fn with_layers_variant(n_conv: usize, variant: KernelVariant) -> NativeBackend {
        let mut be = NativeBackend::with_layers(n_conv);
        be.variant = kernels_simd::resolve(kernels_simd::detected(), variant);
        be
    }

    /// Run `f` with a warm workspace from the backend's shared pool
    /// (fresh on first use; returned afterwards so the buffers recycle).
    /// A panicking `f` drops its workspace instead of poisoning state.
    fn with_ws<R>(&self, f: impl FnOnce(&mut Workspace) -> R) -> R {
        let mut ws = self
            .ws_pool
            .lock()
            .unwrap_or_else(|e| e.into_inner())
            .pop()
            .unwrap_or_default();
        let r = f(&mut ws);
        let mut pool = self.ws_pool.lock().unwrap_or_else(|e| e.into_inner());
        if pool.len() < WS_POOL_CAP {
            pool.push(ws);
        }
        r
    }

    /// Aggregate buffer-reuse counters over the currently idle pooled
    /// workspaces (in-flight ones are counted once they return).
    pub fn workspace_stats(&self) -> WorkspaceStats {
        let pool = self.ws_pool.lock().unwrap_or_else(|e| e.into_inner());
        let mut out = WorkspaceStats::default();
        for ws in pool.iter() {
            let s = ws.stats();
            out.hits += s.hits;
            out.misses += s.misses;
        }
        out
    }

    fn n_conv(&self) -> usize {
        self.manifest.n_conv
    }

    fn readout(&self) -> usize {
        NODE_DIM * (self.n_conv() + 1)
    }

    /// Index of `w_out` in the flat parameter list (`b_out` follows it).
    fn p_w_out(&self) -> usize {
        4 + 4 * self.n_conv()
    }

    fn check_params(&self, params: &Params) -> Result<()> {
        check_params_against(&self.manifest, params)
    }

    /// Full training forward pass, keeping every intermediate backprop
    /// needs. All buffers come from (and are later recycled into) `ws`.
    fn forward(&self, params: &Params, batch: &PackedBatch, ws: &mut Workspace) -> Forward {
        let kk = self.n_conv();
        let readout = self.readout();
        let nn = batch.total_nodes();
        let nb = batch.n_graphs();

        // ---- Fig 5 embedding: e0 = relu(inv·Wi + bi) ++ relu(dep·Wd + bd)
        // — tiled rank-1-update GEMM over the packed node matrix (every
        // row is real; the packed layout has no padding nodes to skip).
        let (w_inv, b_inv) = (&params.values[0], &params.values[1]);
        let (w_dep, b_dep) = (&params.values[2], &params.values[3]);
        let mut e0 = ws.take_f32(nn * NODE_DIM);
        par_rows_into(nn, NODE_DIM, &mut e0, |node, out| {
            kernels::embed_row(
                &batch.inv[node * INV_DIM..(node + 1) * INV_DIM],
                &batch.dep[node * DEP_DIM..(node + 1) * DEP_DIM],
                w_inv,
                b_inv,
                w_dep,
                b_dep,
                out,
            );
        });

        let mut e_list = Vec::with_capacity(kk + 1);
        e_list.push(e0);
        let mut h_list = Vec::with_capacity(kk);
        let mut xhat_list = Vec::with_capacity(kk);
        let mut rstd_list = Vec::with_capacity(kk);
        let mut t = ws.take_f32(nn * NODE_DIM);

        // ---- graph convolutions
        for k in 0..kk {
            let w = &params.values[4 + 4 * k];
            let bvec = &params.values[5 + 4 * k];
            let scale = &params.values[6 + 4 * k];
            let shift = &params.values[7 + 4 * k];
            let e_prev = &e_list[k];

            // t = E · W per node — tiled GEMM, exploiting ReLU sparsity
            par_rows_into(nn, NODE_DIM, &mut t, |node, t_row| {
                kernels::gemm_row(&e_prev[node * NODE_DIM..(node + 1) * NODE_DIM], w, t_row);
            });

            // c = A' · t + b (O(E) gather over the CSR row), then per-node
            // channel norm and ReLU — fused, parallel over row blocks,
            // stashing h/xhat/rstd for backprop
            let mut h = ws.take_f32(nn * NODE_DIM);
            let mut xhat = ws.take_f32(nn * NODE_DIM);
            let mut e_next = ws.take_f32(nn * NODE_DIM);
            let mut rstd = ws.take_f32(nn);
            par_conv_train(
                batch,
                &t,
                bvec,
                scale,
                shift,
                &mut h,
                &mut xhat,
                &mut e_next,
                &mut rstd,
            );
            h_list.push(h);
            xhat_list.push(xhat);
            rstd_list.push(rstd);
            e_list.push(e_next);
        }
        ws.recycle_f32(t);

        // ---- segment-sum readout per conv level + linear head
        let w_out = &params.values[self.p_w_out()];
        let b_out = &params.values[self.p_w_out() + 1];
        let mut feat = ws.take_f32(nb * readout);
        for (k, e) in e_list.iter().enumerate() {
            kernels::readout_level(batch, e, k, readout, &mut feat);
        }
        let mut z = ws.take_f32(nb);
        for g in 0..nb {
            z[g] = kernels::head_row(&feat[g * readout..(g + 1) * readout], w_out, b_out[0]);
        }

        Forward { e: e_list, h: h_list, xhat: xhat_list, rstd: rstd_list, feat, z }
    }

    /// Inference fast path: the same kernel chain as [`Self::forward`],
    /// but ping-ponging two node matrices and folding the readout
    /// incrementally per level — the training stash (`h`/`xhat`/`rstd`,
    /// the per-level activation list) is never materialized. Row kernels
    /// dispatch through `self.variant`: on the default `Scalar` tier the
    /// outputs are bit-identical to the training forward's `z`; SIMD
    /// tiers are held to the `kernels_simd` numeric envelope instead.
    fn infer_ws(&self, params: &Params, batch: &PackedBatch, ws: &mut Workspace) -> Vec<f32> {
        let v = self.variant;
        let kk = self.n_conv();
        let readout = self.readout();
        let nn = batch.total_nodes();
        let nb = batch.n_graphs();

        let mut e = ws.take_f32(nn * NODE_DIM);
        let mut t = ws.take_f32(nn * NODE_DIM);
        let mut feat = ws.take_f32(nb * readout);

        let (w_inv, b_inv) = (&params.values[0], &params.values[1]);
        let (w_dep, b_dep) = (&params.values[2], &params.values[3]);
        par_rows_into(nn, NODE_DIM, &mut e, |node, out| {
            kernels_simd::embed_row_v(
                v,
                &batch.inv[node * INV_DIM..(node + 1) * INV_DIM],
                &batch.dep[node * DEP_DIM..(node + 1) * DEP_DIM],
                w_inv,
                b_inv,
                w_dep,
                b_dep,
                out,
            );
        });
        kernels::readout_level(batch, &e, 0, readout, &mut feat);

        for k in 0..kk {
            let w = &params.values[4 + 4 * k];
            let bvec = &params.values[5 + 4 * k];
            let scale = &params.values[6 + 4 * k];
            let shift = &params.values[7 + 4 * k];
            par_rows_into(nn, NODE_DIM, &mut t, |node, t_row| {
                kernels_simd::gemm_row_v(v, &e[node * NODE_DIM..(node + 1) * NODE_DIM], w, t_row);
            });
            // the gather reads only `t`, so the activations regenerate
            // in place over the dead previous level
            par_rows_into(nn, NODE_DIM, &mut e, |node, row| {
                kernels_simd::conv_row_infer_v(v, batch, &t, node, bvec, scale, shift, row);
            });
            kernels::readout_level(batch, &e, k + 1, readout, &mut feat);
        }

        let w_out = &params.values[self.p_w_out()];
        let b_out = &params.values[self.p_w_out() + 1];
        let mut z = Vec::with_capacity(nb);
        for g in 0..nb {
            z.push(kernels::head_row(&feat[g * readout..(g + 1) * readout], w_out, b_out[0]));
        }
        ws.recycle_f32(e);
        ws.recycle_f32(t);
        ws.recycle_f32(feat);
        z
    }

    /// The training-path forward (full intermediate materialization),
    /// returning only `z`. Exists so the parity tests and the engine
    /// micro-bench can compare the fast path against the full forward.
    pub(crate) fn infer_full(&self, params: &Params, batch: &PackedBatch) -> Result<Vec<f32>> {
        self.check_params(params)?;
        Ok(self.with_ws(|ws| {
            let fwd = self.forward(params, batch, ws);
            let z = fwd.z.clone();
            recycle_forward(ws, fwd);
            z
        }))
    }

    /// Int8 inference fast path: the same loop structure as
    /// [`Self::infer_ws`], with every dense weight product replaced by
    /// the per-channel-dequantizing `qlinear_row` (f32 accumulate, one
    /// scale multiply per output channel). The O(E) CSR gather and the
    /// channel norm stay on the f64 kernels — quantization only touches
    /// the GEMM weights, per the `runtime::quant` format.
    fn infer_quant_ws(
        &self,
        qp: &QuantParams,
        batch: &PackedBatch,
        ws: &mut Workspace,
    ) -> Vec<f32> {
        let v = self.variant;
        let readout = self.readout();
        let nn = batch.total_nodes();
        let nb = batch.n_graphs();

        let mut e = ws.take_f32(nn * NODE_DIM);
        let mut t = ws.take_f32(nn * NODE_DIM);
        let mut feat = ws.take_f32(nb * readout);

        par_rows_into(nn, NODE_DIM, &mut e, |node, out| {
            kernels_simd::qlinear_row_v(
                v,
                &batch.inv[node * INV_DIM..(node + 1) * INV_DIM],
                &qp.w_inv.q,
                &qp.w_inv.scale,
                Some(&qp.b_inv),
                true,
                &mut out[..EMB_INV],
            );
            kernels_simd::qlinear_row_v(
                v,
                &batch.dep[node * DEP_DIM..(node + 1) * DEP_DIM],
                &qp.w_dep.q,
                &qp.w_dep.scale,
                Some(&qp.b_dep),
                true,
                &mut out[EMB_INV..],
            );
        });
        kernels::readout_level(batch, &e, 0, readout, &mut feat);

        for (k, qc) in qp.convs.iter().enumerate() {
            par_rows_into(nn, NODE_DIM, &mut t, |node, t_row| {
                kernels_simd::qlinear_row_v(
                    v,
                    &e[node * NODE_DIM..(node + 1) * NODE_DIM],
                    &qc.w.q,
                    &qc.w.scale,
                    None,
                    false,
                    t_row,
                );
            });
            par_rows_into(nn, NODE_DIM, &mut e, |node, row| {
                kernels_simd::conv_row_infer_v(
                    v,
                    batch,
                    &t,
                    node,
                    &qc.b,
                    &qc.scale,
                    &qc.shift,
                    row,
                );
            });
            kernels::readout_level(batch, &e, k + 1, readout, &mut feat);
        }

        let mut z = Vec::with_capacity(nb);
        let mut zrow = [0f32; 1];
        for g in 0..nb {
            kernels_simd::qlinear_row_v(
                v,
                &feat[g * readout..(g + 1) * readout],
                &qp.w_out.q,
                &qp.w_out.scale,
                Some(&qp.b_out),
                false,
                &mut zrow,
            );
            z.push(zrow[0]);
        }
        ws.recycle_f32(e);
        ws.recycle_f32(t);
        ws.recycle_f32(feat);
        z
    }

    /// Int8 inference entry point (workspace-pooled, same pool as the
    /// f32 path). Predictions are held to the declared
    /// [`crate::runtime::quant`] envelope, not bitwise parity.
    pub fn infer_quant(&self, qp: &QuantParams, batch: &PackedBatch) -> Result<Vec<f32>> {
        ensure!(
            qp.n_conv == self.n_conv(),
            "quantized params have {} conv layers, backend expects {}",
            qp.n_conv,
            self.n_conv()
        );
        Ok(self.with_ws(|ws| self.infer_quant_ws(qp, batch, ws)))
    }

    /// Batched mean-runtime prediction on the int8 path — mirrors the
    /// parallel [`Backend::predict_runtimes`] override (node-balanced
    /// chunks, `exp` of the predicted log-runtime).
    pub fn predict_runtimes_quant(
        &self,
        qp: &QuantParams,
        samples: &[&GraphSample],
        stats: &FeatureStats,
    ) -> Result<Vec<f64>> {
        let chunks = balanced_chunks(samples, num_threads());
        let outs = parallel_map(&chunks, |chunk| -> Result<Vec<f64>> {
            let batch = PackedBatch::for_inference(chunk, stats)?;
            let z = self.infer_quant(qp, &batch)?;
            Ok(z.iter().map(|&v| (v as f64).exp()).collect())
        });
        let mut out = Vec::with_capacity(samples.len());
        for r in outs {
            out.extend(r?);
        }
        Ok(out)
    }

    /// Analytic gradients of the §III-C loss w.r.t. every parameter
    /// (weight decay is applied later, in the Adagrad step — matching
    /// `model.train_step`), parallel over graph-aligned row blocks with
    /// `threads` workers. Each block runs the entire backward pass for
    /// its graphs (the block-diagonal adjacency keeps it self-contained)
    /// into private gradient accumulators; block results are reduced in
    /// fixed block order, so the output is bitwise-identical for every
    /// `threads` value.
    fn backward_threads(
        &self,
        params: &Params,
        batch: &PackedBatch,
        fwd: &Forward,
        dz: &[f64],
        ws: &mut Workspace,
        threads: usize,
    ) -> Vec<Vec<f64>> {
        let kk = self.n_conv();
        let readout = self.readout();
        let iw = self.p_w_out();
        let nn = batch.total_nodes();
        let blocks = batch.graph_blocks(BACKWARD_BLOCK_NODES);
        // build the transpose once, before the fan-out
        let adj_t = batch.adj_t();

        let node_ranges: Vec<Range<usize>> = blocks
            .iter()
            .map(|gr| batch.node_offset[gr.start] as usize..batch.node_offset[gr.end] as usize)
            .collect();

        // per-block scratch: disjoint slices of four shared node buffers
        let mut de_buf = ws.take_f64(nn * NODE_DIM);
        let mut de_next_buf = ws.take_f64(nn * NODE_DIM);
        let mut dc_buf = ws.take_f64(nn * NODE_DIM);
        let mut dt_buf = ws.take_f64(nn * NODE_DIM);
        let results = {
            let mut de_parts = split_rows(&mut de_buf, &node_ranges, NODE_DIM).into_iter();
            let mut de_next_parts =
                split_rows(&mut de_next_buf, &node_ranges, NODE_DIM).into_iter();
            let mut dc_parts = split_rows(&mut dc_buf, &node_ranges, NODE_DIM).into_iter();
            let mut dt_parts = split_rows(&mut dt_buf, &node_ranges, NODE_DIM).into_iter();
            let mut tasks = Vec::with_capacity(blocks.len());
            for (gr, nr) in blocks.iter().zip(&node_ranges) {
                tasks.push(BlockTask {
                    graphs: gr.clone(),
                    nodes: nr.clone(),
                    de: de_parts.next().unwrap(),
                    de_next: de_next_parts.next().unwrap(),
                    dc: dc_parts.next().unwrap(),
                    dt: dt_parts.next().unwrap(),
                });
            }
            parallel_map_vec_threads(tasks, threads, |task| {
                backward_block(params, batch, fwd, dz, adj_t, kk, readout, iw, task)
            })
        };
        ws.recycle_f64(de_buf);
        ws.recycle_f64(de_next_buf);
        ws.recycle_f64(dc_buf);
        ws.recycle_f64(dt_buf);

        // deterministic reduction: block results added in block order
        let mut grads: Vec<Vec<f64>> =
            params.values.iter().map(|v| vec![0f64; v.len()]).collect();
        for bg in results {
            for (g, b) in grads.iter_mut().zip(bg) {
                for (gi, bv) in g.iter_mut().zip(b) {
                    *gi += bv;
                }
            }
        }
        grads
    }
}

/// Validate a flat parameter list against a manifest (shared with the
/// dense reference engine).
pub(crate) fn check_params_against(manifest: &Manifest, params: &Params) -> Result<()> {
    ensure!(
        params.values.len() == manifest.params.len(),
        "backend expects {} param tensors, got {}",
        manifest.params.len(),
        params.values.len()
    );
    for (v, spec) in params.values.iter().zip(&manifest.params) {
        ensure!(
            v.len() == spec.numel(),
            "param '{}' has {} elements, manifest expects {}",
            spec.name,
            v.len(),
            spec.numel()
        );
    }
    Ok(())
}

/// Training conv layer, parallel over row blocks: gather + norm + ReLU
/// per node, writing `h`/`xhat`/`e_next`/`rstd` directly into the
/// caller's buffers (disjoint block slices — no staging copies).
fn par_conv_train(
    batch: &PackedBatch,
    t: &[f32],
    bvec: &[f32],
    scale: &[f32],
    shift: &[f32],
    h: &mut [f32],
    xhat: &mut [f32],
    e_next: &mut [f32],
    rstd: &mut [f32],
) {
    let nn = batch.total_nodes();
    if nn <= PAR_MIN_ROWS {
        conv_train_block(batch, t, bvec, scale, shift, 0..nn, h, xhat, e_next, rstd);
        return;
    }
    let ranges = chunk_ranges(nn, PAR_MIN_ROWS);
    if ranges.len() <= 1 {
        conv_train_block(batch, t, bvec, scale, shift, 0..nn, h, xhat, e_next, rstd);
        return;
    }
    let mut hs = split_rows(h, &ranges, NODE_DIM).into_iter();
    let mut xs = split_rows(xhat, &ranges, NODE_DIM).into_iter();
    let mut es = split_rows(e_next, &ranges, NODE_DIM).into_iter();
    let mut rs = split_rows(rstd, &ranges, 1).into_iter();
    let mut tasks = Vec::with_capacity(ranges.len());
    for range in &ranges {
        tasks.push((
            range.clone(),
            hs.next().unwrap(),
            xs.next().unwrap(),
            es.next().unwrap(),
            rs.next().unwrap(),
        ));
    }
    parallel_map_vec(tasks, |(range, h, x, e, r)| {
        conv_train_block(batch, t, bvec, scale, shift, range, h, x, e, r)
    });
}

/// One contiguous row block of the training conv layer. Free function
/// (not a closure) so it can be called with block slices of any
/// lifetime from both the serial and the parallel paths.
fn conv_train_block(
    batch: &PackedBatch,
    t: &[f32],
    bvec: &[f32],
    scale: &[f32],
    shift: &[f32],
    range: Range<usize>,
    h: &mut [f32],
    xhat: &mut [f32],
    e_next: &mut [f32],
    rstd: &mut [f32],
) {
    for (i, node) in range.enumerate() {
        let o = i * NODE_DIM;
        rstd[i] = kernels::conv_row_train(
            batch,
            t,
            node,
            bvec,
            scale,
            shift,
            &mut h[o..o + NODE_DIM],
            &mut xhat[o..o + NODE_DIM],
            &mut e_next[o..o + NODE_DIM],
        );
    }
}

/// One backward block: the graphs `graphs` (packed nodes `nodes`) plus
/// this block's disjoint slices of the shared scratch buffers. All node
/// indices inside the scratch slices are block-local (`global - nodes.start`);
/// reads of the forward stash and the batch stay global.
struct BlockTask<'a> {
    graphs: Range<usize>,
    nodes: Range<usize>,
    de: &'a mut [f64],
    de_next: &'a mut [f64],
    dc: &'a mut [f64],
    dt: &'a mut [f64],
}

/// Run the entire backward pass for one graph-aligned block, returning
/// the block's private gradient accumulators (summed into the final
/// gradients in block order by the caller).
fn backward_block(
    params: &Params,
    batch: &PackedBatch,
    fwd: &Forward,
    dz: &[f64],
    adj_t: &Csr,
    kk: usize,
    readout: usize,
    iw: usize,
    task: BlockTask<'_>,
) -> Vec<Vec<f64>> {
    let BlockTask { graphs, nodes, mut de, mut de_next, dc, dt } = task;
    let base = nodes.start;
    let nloc = nodes.len();
    let w_out = &params.values[iw];
    let mut grads: Vec<Vec<f64>> = params.values.iter().map(|v| vec![0f64; v.len()]).collect();

    // ---- head: z = feat · w_out + b_out
    for g in graphs.clone() {
        if dz[g] == 0.0 {
            continue;
        }
        grads[iw + 1][0] += dz[g];
        for r in 0..readout {
            grads[iw][r] += fwd.feat[g * readout + r] as f64 * dz[g];
        }
    }

    // dL/de for the deepest activations: the level-kk segment-sum
    // readout broadcasts dz · w_out[kk·F + j] to every node of the graph.
    for v in de.iter_mut() {
        *v = 0.0;
    }
    for g in graphs.clone() {
        if dz[g] == 0.0 {
            continue;
        }
        for node in batch.graph_nodes(g) {
            let lo = (node - base) * NODE_DIM;
            for j in 0..NODE_DIM {
                de[lo + j] = dz[g] * w_out[kk * NODE_DIM + j] as f64;
            }
        }
    }

    // ---- conv layers, deepest first
    for k in (0..kk).rev() {
        let w = &params.values[4 + 4 * k];
        let scale = &params.values[6 + 4 * k];
        let h = &fwd.h[k];
        let xh = &fwd.xhat[k];
        let rstd = &fwd.rstd[k];
        let e_prev = &fwd.e[k];

        // ReLU + channel-norm backward: de -> dc (per node)
        for ln in 0..nloc {
            let node = base + ln;
            let o = node * NODE_DIM;
            let lo = ln * NODE_DIM;
            let mut dxh = [0f64; NODE_DIM];
            let mut sum1 = 0f64;
            let mut sum2 = 0f64;
            for j in 0..NODE_DIM {
                let dh = if h[o + j] > 0.0 { de[lo + j] } else { 0.0 };
                grads[6 + 4 * k][j] += dh * xh[o + j] as f64;
                grads[7 + 4 * k][j] += dh;
                let dx = dh * scale[j] as f64;
                dxh[j] = dx;
                sum1 += dx;
                sum2 += dx * xh[o + j] as f64;
            }
            let rs = rstd[node] as f64;
            for j in 0..NODE_DIM {
                let v = rs * (dxh[j] - (sum1 + xh[o + j] as f64 * sum2) / NODE_DIM as f64);
                dc[lo + j] = v;
                grads[5 + 4 * k][j] += v;
            }
        }

        // dt = A'ᵀ · dc — O(E) gather over the transpose CSR. The
        // adjacency is block-diagonal and the block is graph-aligned, so
        // every referenced row lives inside this block's scratch.
        for v in dt.iter_mut() {
            *v = 0.0;
        }
        for ln in 0..nloc {
            let (rows, vals) = adj_t.row(base + ln);
            let lo = ln * NODE_DIM;
            for (&r, &a) in rows.iter().zip(vals) {
                let af = a as f64;
                let src = &dc[(r as usize - base) * NODE_DIM..(r as usize - base + 1) * NODE_DIM];
                for j in 0..NODE_DIM {
                    dt[lo + j] += af * src[j];
                }
            }
        }

        // de_prev = dt · Wᵀ and dW += e_prevᵀ · dt
        for ln in 0..nloc {
            let node = base + ln;
            let lo = ln * NODE_DIM;
            let dtrow = &dt[lo..lo + NODE_DIM];
            let erow = &e_prev[node * NODE_DIM..(node + 1) * NODE_DIM];
            for i in 0..NODE_DIM {
                let wrow = &w[i * NODE_DIM..(i + 1) * NODE_DIM];
                let mut acc = 0f64;
                for j in 0..NODE_DIM {
                    acc += dtrow[j] * wrow[j] as f64;
                }
                de_next[lo + i] = acc;
                let ev = erow[i] as f64;
                if ev != 0.0 {
                    let gw = &mut grads[4 + 4 * k][i * NODE_DIM..(i + 1) * NODE_DIM];
                    for j in 0..NODE_DIM {
                        gw[j] += ev * dtrow[j];
                    }
                }
            }
        }

        // segment-sum readout gradient for level k
        for g in graphs.clone() {
            if dz[g] == 0.0 {
                continue;
            }
            for node in batch.graph_nodes(g) {
                let lo = (node - base) * NODE_DIM;
                for j in 0..NODE_DIM {
                    de_next[lo + j] += dz[g] * w_out[k * NODE_DIM + j] as f64;
                }
            }
        }
        std::mem::swap(&mut de, &mut de_next);
    }

    // ---- embedding backward
    let e0 = &fwd.e[0];
    for ln in 0..nloc {
        let node = base + ln;
        let o = node * NODE_DIM;
        let lo = ln * NODE_DIM;
        let inv = &batch.inv[node * INV_DIM..(node + 1) * INV_DIM];
        let dep = &batch.dep[node * DEP_DIM..(node + 1) * DEP_DIM];
        for j in 0..EMB_INV {
            if e0[o + j] <= 0.0 {
                continue;
            }
            let g = de[lo + j];
            if g == 0.0 {
                continue;
            }
            grads[1][j] += g;
            for (i, &x) in inv.iter().enumerate() {
                grads[0][i * EMB_INV + j] += x as f64 * g;
            }
        }
        for j in 0..EMB_DEP {
            if e0[o + EMB_INV + j] <= 0.0 {
                continue;
            }
            let g = de[lo + EMB_INV + j];
            if g == 0.0 {
                continue;
            }
            grads[3][j] += g;
            for (i, &x) in dep.iter().enumerate() {
                grads[2][i * EMB_DEP + j] += x as f64 * g;
            }
        }
    }

    grads
}

/// Forward intermediates kept for the backward pass. Buffers are arena
/// property: return them via [`recycle_forward`] after the step.
struct Forward {
    /// Node activations per level: `e[k]` for k = 0..=n_conv, each flat
    /// `[total_nodes, NODE_DIM]`.
    e: Vec<Vec<f32>>,
    /// Post-norm pre-ReLU activations per conv layer.
    h: Vec<Vec<f32>>,
    /// Normalized (pre scale/shift) activations per conv layer.
    xhat: Vec<Vec<f32>>,
    /// Reciprocal std per node per conv layer, flat `[total_nodes]`.
    rstd: Vec<Vec<f32>>,
    /// Segment-summed readout features, flat `[n_graphs, READOUT]`.
    feat: Vec<f32>,
    /// Predicted log-runtime per graph.
    z: Vec<f32>,
}

/// Return every forward buffer to the workspace arena.
fn recycle_forward(ws: &mut Workspace, fwd: Forward) {
    for v in fwd.e {
        ws.recycle_f32(v);
    }
    for v in fwd.h {
        ws.recycle_f32(v);
    }
    for v in fwd.xhat {
        ws.recycle_f32(v);
    }
    for v in fwd.rstd {
        ws.recycle_f32(v);
    }
    ws.recycle_f32(fwd.feat);
    ws.recycle_f32(fwd.z);
}

/// The §III-C ξ loss term and its derivative at `d = z − log ȳ`:
/// `ξ = |expm1(clamp(d, ±3))| + |d − clamp(d, ±3)|·e³`.
pub(crate) fn xi_and_grad(d: f64) -> (f64, f64) {
    let e3 = LOSS_CLIP.exp();
    let dclamped = d.clamp(-LOSS_CLIP, LOSS_CLIP);
    let xi = dclamped.exp_m1().abs() + (d - dclamped).abs() * e3;
    let g = if d > LOSS_CLIP {
        e3
    } else if d < -LOSS_CLIP {
        -e3
    } else if d > 0.0 {
        d.exp()
    } else if d < 0.0 {
        -d.exp()
    } else {
        0.0
    };
    (xi, g)
}

/// §III-C loss and its gradient w.r.t. z: the `weight`-weighted mean of ξ
/// over the batch's graphs.
pub(crate) fn loss_and_dz(z: &[f32], batch: &PackedBatch) -> (f64, Vec<f64>) {
    let nb = batch.n_graphs();
    let mut wsum = 0f64;
    for g in 0..nb {
        wsum += batch.weight[g] as f64;
    }
    let denom = wsum.max(1e-6);
    let mut loss = 0f64;
    let mut dz = vec![0f64; nb];
    for g in 0..nb {
        let w = batch.weight[g] as f64;
        if w == 0.0 {
            continue;
        }
        let d = z[g] as f64 - batch.log_y[g] as f64;
        let (xi, gr) = xi_and_grad(d);
        loss += w * xi;
        dz[g] = w * gr / denom;
    }
    (loss / denom, dz)
}

/// Adagrad with weight decay: `g += wd·p; a += g²; p −= lr·g/(√a + ε)`.
pub(crate) fn apply_adagrad(
    params: &mut Params,
    accum: &mut Params,
    grads: &[Vec<f64>],
    lr: f64,
    wd: f64,
) {
    for (t, g) in grads.iter().enumerate() {
        let pv = &mut params.values[t];
        let av = &mut accum.values[t];
        for i in 0..g.len() {
            let gi = g[i] + wd * pv[i] as f64;
            let a = av[i] as f64 + gi * gi;
            av[i] = a as f32;
            pv[i] = (pv[i] as f64 - lr * gi / (a.sqrt() + ADAGRAD_EPS)) as f32;
        }
    }
}

impl Backend for NativeBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn kernel_variant(&self) -> KernelVariant {
        self.variant
    }

    /// The inference fast path (see `infer_ws`): zero steady-state node
    /// allocation, no training stash.
    fn infer(&self, params: &Params, batch: &PackedBatch) -> Result<Vec<f32>> {
        self.check_params(params)?;
        Ok(self.with_ws(|ws| self.infer_ws(params, batch, ws)))
    }

    fn train_step_lr(
        &self,
        params: &mut Params,
        accum: &mut Params,
        batch: &PackedBatch,
        lr: f32,
    ) -> Result<f32> {
        self.check_params(params)?;
        self.check_params(accum)?;
        let loss = self.with_ws(|ws| {
            let fwd = self.forward(params, batch, ws);
            let (loss, dz) = loss_and_dz(&fwd.z, batch);
            let grads = self.backward_threads(params, batch, &fwd, &dz, ws, num_threads());
            apply_adagrad(params, accum, &grads, lr as f64, self.manifest.weight_decay);
            recycle_forward(ws, fwd);
            loss
        });
        Ok(loss as f32)
    }

    /// Parallel over batch chunks balanced by total packed nodes: each
    /// worker packs its own batch and runs the fast-path forward
    /// independently (the backend is stateless). Every chunk goes
    /// through the same [`predict_chunk`] helper as the sequential trait
    /// default, and predictions are chunk-invariant, so the policy only
    /// moves work between threads.
    fn predict_runtimes(
        &self,
        params: &Params,
        samples: &[&GraphSample],
        stats: &FeatureStats,
    ) -> Result<Vec<f64>> {
        self.check_params(params)?;
        let chunks = balanced_chunks(samples, num_threads());
        let outs = parallel_map(&chunks, |chunk| predict_chunk(self, params, chunk, stats));
        let mut out = Vec::with_capacity(samples.len());
        for r in outs {
            out.extend(r?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::dense_ref::DenseRefBackend;
    use crate::testfix::{
        chain_sample, grad_fixture_batch, identity_stats, parity_batch, parity_params,
        synth_packed_batch, synth_sample, REF_GRADS, REF_LOSS, REF_Z,
    };
    use crate::util::alloc_count::thread_alloc_count;
    use crate::util::propcheck;
    use crate::util::rng::Rng;

    #[test]
    fn forward_matches_jax_reference_through_packed_conversion() {
        let be = NativeBackend::new();
        let dense = parity_batch();
        let batch = PackedBatch::from_dense(&dense).unwrap();
        let params = parity_params(be.manifest());
        let z = be.infer(&params, &batch).unwrap();
        assert_eq!(z.len(), BATCH);
        for (i, (&got, &want)) in z.iter().zip(REF_Z.iter()).enumerate() {
            assert!(
                (got - want).abs() <= 1e-5,
                "z[{i}] = {got}, reference {want} (|diff| = {})",
                (got - want).abs()
            );
        }
    }

    #[test]
    fn backward_matches_jax_grads_through_packed_conversion() {
        let be = NativeBackend::new();
        let batch = PackedBatch::from_dense(&grad_fixture_batch()).unwrap();
        let params = parity_params(be.manifest());
        let mut ws = Workspace::new();
        let fwd = be.forward(&params, &batch, &mut ws);
        let (loss, dz) = loss_and_dz(&fwd.z, &batch);
        assert!(
            (loss - REF_LOSS).abs() < 5e-3,
            "loss {loss} vs jax reference {REF_LOSS}"
        );
        let grads = be.backward_threads(&params, &batch, &fwd, &dz, &mut ws, num_threads());
        for &(t, i, want) in REF_GRADS.iter() {
            let got = grads[t][i];
            let tol = 1e-3 + 2e-3 * want.abs();
            assert!(
                (got - want).abs() <= tol,
                "grad[{t}][{i}] = {got}, jax reference {want} (tol {tol})"
            );
        }
    }

    /// A random sample with arbitrary node count (beyond the old 48-node
    /// cap), arbitrary edges and dense-ish random features.
    fn random_sample(rng: &mut Rng, max_nodes: usize, pid: u32) -> GraphSample {
        let n = 1 + rng.gen_range(max_nodes);
        let mut edges = Vec::new();
        for _ in 0..rng.gen_range(3 * n + 1) {
            edges.push((rng.gen_range(n) as u32, rng.gen_range(n) as u32));
        }
        let mut inv = vec![[0f32; INV_DIM]; n];
        let mut dep = vec![[0f32; DEP_DIM]; n];
        for s in 0..n {
            for v in inv[s].iter_mut() {
                *v = rng.uniform(-2.0, 2.0) as f32;
            }
            for v in dep[s].iter_mut() {
                *v = rng.uniform(-2.0, 2.0) as f32;
            }
        }
        let mut runs = [0f32; crate::constants::BENCH_RUNS];
        let base = rng.uniform(1e-4, 1e-2);
        for r in runs.iter_mut() {
            *r = (base * rng.uniform(0.9, 1.1)) as f32;
        }
        GraphSample {
            pipeline_id: pid,
            schedule_id: 0,
            n_stages: n as u32,
            edges,
            inv,
            dep,
            runs,
        }
    }

    /// Property parity: for random variable-size graphs (including well
    /// past the old 48-stage cap), the sparse forward and backward match
    /// the dense reference engine within 1e-5.
    #[test]
    fn prop_sparse_matches_dense_reference() {
        let sparse = NativeBackend::new();
        let dense = DenseRefBackend::new();
        propcheck::check_rng("sparse vs dense-ref parity", 0x5EED, 10, |rng| {
            let n_graphs = 1 + rng.gen_range(5);
            let samples: Vec<GraphSample> = (0..n_graphs)
                .map(|g| random_sample(rng, 80, g as u32))
                .collect();
            let refs: Vec<&GraphSample> = samples.iter().collect();
            let min_rt = refs
                .iter()
                .map(|s| s.mean_runtime())
                .fold(f64::INFINITY, f64::min);
            let best = vec![min_rt; refs.len()];
            let batch = PackedBatch::build(&refs, &identity_stats(), &best)
                .map_err(|e| e.to_string())?;

            let params = sparse.init_params(rng.next_u64());
            let zs = sparse.infer(&params, &batch).map_err(|e| e.to_string())?;
            let zd = dense.infer(&params, &batch).map_err(|e| e.to_string())?;
            if zs.len() != zd.len() {
                return Err(format!("length mismatch {} vs {}", zs.len(), zd.len()));
            }
            for (i, (a, b)) in zs.iter().zip(&zd).enumerate() {
                if (a - b).abs() > 1e-5 {
                    return Err(format!("forward diverges at graph {i}: {a} vs {b}"));
                }
            }

            let mut ps = params.clone();
            let mut as_ = ps.zeros_like();
            let mut pd = params.clone();
            let mut ad = pd.zeros_like();
            let ls = sparse
                .train_step_lr(&mut ps, &mut as_, &batch, 0.01)
                .map_err(|e| e.to_string())?;
            let ld = dense
                .train_step_lr(&mut pd, &mut ad, &batch, 0.01)
                .map_err(|e| e.to_string())?;
            if (ls - ld).abs() > 1e-5 * ld.abs().max(1.0) {
                return Err(format!("loss diverges: sparse {ls} vs dense {ld}"));
            }
            for (t, (vs, vd)) in ps.values.iter().zip(&pd.values).enumerate() {
                for (i, (a, b)) in vs.iter().zip(vd).enumerate() {
                    if (a - b).abs() > 1e-5 {
                        return Err(format!(
                            "post-step param[{t}][{i}] diverges: {a} vs {b}"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    /// The tentpole's core parity bar: the inference fast path and the
    /// full training forward share kernels and summation chains, so
    /// their outputs must match *bitwise* — across the whole zoo,
    /// including the 59-stage resnet50 the padded layout could not even
    /// represent.
    #[test]
    fn fast_path_matches_full_forward_bitwise_across_zoo() {
        use crate::dataset::builder::sample_from_schedule;
        use crate::lower::lower_pipeline;
        use crate::schedule::random::random_pipeline_schedule;
        use crate::sim::Machine;

        let machine = Machine::default();
        let mut rng = Rng::new(0xFA57);
        let mut samples = Vec::new();
        let nets = [crate::zoo::resnet50(), crate::zoo::resnet18(), crate::zoo::unet()];
        for (pid, net) in nets.iter().enumerate() {
            let nests = lower_pipeline(net);
            for sid in 0..3u32 {
                let sched = random_pipeline_schedule(net, &nests, &mut rng);
                samples.push(sample_from_schedule(
                    net, &nests, &sched, &machine, pid as u32, sid, &mut rng,
                ));
            }
        }
        assert!(samples.iter().any(|s| s.n_stages > 48), "zoo must exceed the old cap");
        let refs: Vec<&GraphSample> = samples.iter().collect();
        let batch = PackedBatch::for_inference(&refs, &identity_stats()).unwrap();

        for layers in [0usize, 1, 2] {
            let be = NativeBackend::with_layers(layers);
            let params = be.init_params(42 + layers as u64);
            let fast = be.infer(&params, &batch).unwrap();
            let full = be.infer_full(&params, &batch).unwrap();
            assert_eq!(
                fast, full,
                "fast path diverged from the training forward at {layers} conv layers"
            );
        }
    }

    /// Parallel backward must be bitwise-deterministic across thread
    /// counts: the graph-aligned block partition depends only on the
    /// batch and blocks are reduced in fixed order.
    #[test]
    fn parallel_backward_is_bitwise_deterministic_across_thread_counts() {
        let be = NativeBackend::new();
        let mut rng = Rng::new(0xB10C);
        let samples: Vec<GraphSample> =
            (0..30).map(|g| random_sample(&mut rng, 80, g as u32)).collect();
        let refs: Vec<&GraphSample> = samples.iter().collect();
        let min_rt = refs.iter().map(|s| s.mean_runtime()).fold(f64::INFINITY, f64::min);
        let best = vec![min_rt; refs.len()];
        let batch = PackedBatch::build(&refs, &identity_stats(), &best).unwrap();
        assert!(
            batch.graph_blocks(BACKWARD_BLOCK_NODES).len() >= 2,
            "fixture must span multiple backward blocks ({} nodes)",
            batch.total_nodes()
        );
        let params = be.init_params(9);
        let mut ws = Workspace::new();
        let fwd = be.forward(&params, &batch, &mut ws);
        let (_, dz) = loss_and_dz(&fwd.z, &batch);
        let reference = be.backward_threads(&params, &batch, &fwd, &dz, &mut ws, 1);
        for threads in [2usize, 4, 7] {
            let grads = be.backward_threads(&params, &batch, &fwd, &dz, &mut ws, threads);
            assert_eq!(
                reference, grads,
                "backward gradients changed at {threads} threads"
            );
        }
    }

    /// The workspace contract: once the backend's arena pool has seen a
    /// workload's shapes, repeated inference performs no node-matrix
    /// allocation — only the returned z vector (and nothing proportional
    /// to the node count) touches the heap.
    #[test]
    fn inference_fast_path_has_zero_steady_state_node_allocations() {
        let be = NativeBackend::new();
        let batch = synth_packed_batch();
        let params = be.init_params(3);
        // warm the backend's workspace pool until it stabilizes
        for _ in 0..3 {
            be.infer(&params, &batch).unwrap();
        }
        let misses0 = be.workspace_stats().misses;
        let hits0 = be.workspace_stats().hits;
        let before = thread_alloc_count();
        let calls = 5u64;
        for _ in 0..calls {
            be.infer(&params, &batch).unwrap();
        }
        let allocs = thread_alloc_count() - before;
        let misses1 = be.workspace_stats().misses;
        let hits1 = be.workspace_stats().hits;
        assert_eq!(misses1, misses0, "steady-state infer must reuse pooled buffers");
        assert!(hits1 > hits0, "steady-state infer must hit the pool");
        assert!(
            allocs <= 3 * calls,
            "steady-state infer allocated {allocs} times over {calls} calls"
        );
    }

    #[test]
    fn adagrad_training_reduces_loss_over_50_steps() {
        let be = NativeBackend::new();
        let batch = synth_packed_batch();
        // deterministic patterned init (the JAX simulation of this exact
        // fixture converges 6.06 -> 0.33 in 50 steps at lr 0.01)
        let mut params = parity_params(be.manifest());
        // output-bias init at the batch mean log-runtime (as train() does)
        let nb = batch.n_graphs();
        let mean_log_y: f32 = batch.log_y.iter().sum::<f32>() / nb as f32;
        params.values.last_mut().unwrap()[0] = mean_log_y;
        let mut accum = params.zeros_like();
        let mut losses = Vec::with_capacity(50);
        for _ in 0..50 {
            let l = be.train_step_lr(&mut params, &mut accum, &batch, 0.01).unwrap();
            assert!(l.is_finite(), "loss must stay finite");
            losses.push(l);
        }
        assert!(
            losses[49] < losses[0],
            "50 Adagrad steps must reduce the loss: {} -> {}",
            losses[0],
            losses[49]
        );
        // and decisively so on a memorizable single batch
        assert!(
            losses[49] < losses[0] * 0.5,
            "expected >2x loss reduction: {} -> {}",
            losses[0],
            losses[49]
        );
    }

    #[test]
    fn infer_is_deterministic_across_repeats() {
        let be = NativeBackend::new();
        let samples: Vec<GraphSample> =
            (0..5).map(|i| synth_sample(0, i, 1e-3 * (1.0 + i as f32))).collect();
        let refs: Vec<&GraphSample> = samples.iter().collect();
        let batch = PackedBatch::for_inference(&refs, &identity_stats()).unwrap();
        let params = be.init_params(3);
        let z1 = be.infer(&params, &batch).unwrap();
        let z2 = be.infer(&params, &batch).unwrap();
        assert_eq!(z1.len(), 5);
        assert_eq!(z1, z2);
        assert!(z1.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn graphs_beyond_the_old_cap_run() {
        // 200 stages — impossible to even represent in the padded layout
        let be = NativeBackend::new();
        let big = GraphSample {
            pipeline_id: 7,
            schedule_id: 0,
            n_stages: 200,
            edges: (0..199).map(|i| (i as u32, (i + 1) as u32)).collect(),
            inv: vec![[0.1; INV_DIM]; 200],
            dep: vec![[0.2; DEP_DIM]; 200],
            runs: [1e-3; crate::constants::BENCH_RUNS],
        };
        let refs = vec![&big];
        let batch = PackedBatch::for_inference(&refs, &identity_stats()).unwrap();
        assert_eq!(batch.total_nodes(), 200);
        let params = be.init_params(2);
        let z = be.infer(&params, &batch).unwrap();
        assert_eq!(z.len(), 1);
        assert!(z[0].is_finite());
    }

    #[test]
    fn predict_runtimes_parallel_matches_sequential() {
        let be = NativeBackend::new();
        let samples: Vec<GraphSample> = (0..70)
            .map(|i| synth_sample((i / 10) as u32, (i % 10) as u32, 1e-3 * (1.0 + i as f32)))
            .collect();
        let refs: Vec<&GraphSample> = samples.iter().collect();
        let stats = identity_stats();
        let params = be.init_params(11);
        let parallel = be.predict_runtimes(&params, &refs, &stats).unwrap();
        assert_eq!(parallel.len(), 70);

        // sequential reference: one packed batch per fixed-size chunk —
        // predictions are chunk-invariant, so the node-balanced policy
        // must reproduce this bitwise
        let mut sequential = Vec::new();
        for chunk in refs.chunks(BATCH) {
            let batch = PackedBatch::for_inference(chunk, &stats).unwrap();
            let z = be.infer(&params, &batch).unwrap();
            sequential.extend(z.iter().map(|&v| (v as f64).exp()));
        }
        assert_eq!(parallel, sequential);
        assert!(parallel.iter().all(|p| p.is_finite() && *p > 0.0));
    }

    /// The straggler fix: chunks are balanced by packed nodes, so one
    /// 59-stage graph in a sea of tiny ones gets (roughly) its own chunk
    /// instead of dragging a full BATCH of extra work behind it.
    #[test]
    fn predict_chunking_balances_by_nodes() {
        let mut samples: Vec<GraphSample> =
            (0..40).map(|i| chain_sample(5, 1e-3 * (1.0 + i as f32))).collect();
        samples.insert(17, chain_sample(59, 2e-3));
        let refs: Vec<&GraphSample> = samples.iter().collect();
        let workers = 4usize;
        let chunks = balanced_chunks(&refs, workers);

        // chunks tile the samples contiguously, in order
        let recombined: usize = chunks.iter().map(|c| c.len()).sum();
        assert_eq!(recombined, refs.len());
        assert!(chunks.len() > 1);

        let total_nodes: usize = refs.iter().map(|s| s.n_stages as usize).sum();
        let budget = total_nodes.div_ceil(workers * 4).max(1);
        for c in &chunks {
            let nodes: usize = c.iter().map(|s| s.n_stages as usize).sum();
            assert!(
                c.len() == 1 || nodes <= budget,
                "multi-sample chunk holds {nodes} nodes (budget {budget})"
            );
            assert!(c.len() <= BATCH);
        }
        // the big graph rides (near-)alone rather than with a full batch
        let big_chunk = chunks
            .iter()
            .find(|c| c.iter().any(|s| s.n_stages == 59))
            .expect("the 59-stage graph must land in some chunk");
        assert!(
            big_chunk.len() <= 2,
            "59-stage graph was grouped with {} small graphs",
            big_chunk.len() - 1
        );

        // degenerate inputs
        assert!(balanced_chunks(&[], workers).is_empty());
        let one = [refs[0]];
        assert_eq!(balanced_chunks(&one, workers).len(), 1);
    }

    #[test]
    fn chunk_graph_cap_derives_from_node_budget() {
        // ~600-node graphs under a 1200-node budget: the derived cap is
        // 2 graphs per chunk and no multi-graph chunk tops the budget
        let samples: Vec<GraphSample> =
            (0..8).map(|i| chain_sample(600, 1e-3 * (1.0 + i as f32))).collect();
        let refs: Vec<&GraphSample> = samples.iter().collect();
        let chunks = balanced_chunks_with(&refs, 1, 1200);
        let covered: usize = chunks.iter().map(|c| c.len()).sum();
        assert_eq!(covered, refs.len());
        for c in &chunks {
            assert!(c.len() <= 2, "cap should be 1200/600 = 2, got {}", c.len());
            let nodes: usize = c.iter().map(|s| s.n_stages as usize).sum();
            assert!(c.len() == 1 || nodes <= 1200, "{nodes} nodes in one chunk");
        }
        // a graph bigger than the whole budget still rides alone
        let big = [chain_sample(5000, 1e-3)];
        let big_refs: Vec<&GraphSample> = big.iter().collect();
        assert_eq!(balanced_chunks_with(&big_refs, 4, 1200).len(), 1);
    }

    #[test]
    fn ablation_depths_run_natively() {
        for layers in [0usize, 1, 4] {
            let be = NativeBackend::with_layers(layers);
            assert_eq!(be.manifest().params.len(), 6 + 4 * layers);
            let batch = synth_packed_batch();
            let params = be.init_params(5);
            let z = be.infer(&params, &batch).unwrap();
            assert_eq!(z.len(), batch.n_graphs());
            assert!(z.iter().all(|v| v.is_finite()));
            let mut p = params.clone();
            let mut a = p.zeros_like();
            let l = be.train_step_lr(&mut p, &mut a, &batch, 0.01).unwrap();
            assert!(l.is_finite());
        }
    }

    #[test]
    fn check_params_rejects_wrong_layout() {
        let be = NativeBackend::new();
        let wrong = be.init_params(1);
        let be0 = NativeBackend::with_layers(0);
        let batch = synth_packed_batch();
        assert!(be0.infer(&wrong, &batch).is_err());
    }

    /// SIMD numeric-mode contract: every tier this build + CPU can run
    /// stays within `SIMD_REL_TOL` of the scalar reference per predicted
    /// log-runtime. In a default (no-`simd`) build every request clamps
    /// to Scalar and the comparison degenerates to bitwise equality.
    #[test]
    fn simd_variants_match_scalar_within_envelope() {
        use crate::runtime::kernels_simd::{detected, resolve, SIMD_REL_TOL};
        let scalar = NativeBackend::new();
        let batch = synth_packed_batch();
        let params = scalar.init_params(21);
        let zs = scalar.infer(&params, &batch).unwrap();
        for req in [KernelVariant::Sse2, KernelVariant::Avx2] {
            let be = NativeBackend::with_variant(req);
            assert_eq!(be.kernel_variant(), resolve(detected(), req));
            let zv = be.infer(&params, &batch).unwrap();
            assert_eq!(zv.len(), zs.len());
            for (i, (a, b)) in zv.iter().zip(&zs).enumerate() {
                let tol = SIMD_REL_TOL * (b.abs() as f64).max(1.0);
                assert!(
                    ((a - b).abs() as f64) <= tol,
                    "variant {req:?} diverges at graph {i}: {a} vs {b} (tol {tol})"
                );
            }
        }
    }

    /// Forced-fallback contract: requesting a tier beyond what this
    /// build or CPU supports clamps down and still runs correctly; when
    /// it clamps all the way to Scalar (always true without the `simd`
    /// feature) the result is bitwise-identical to the default engine.
    #[test]
    fn requesting_unavailable_variant_falls_back_cleanly() {
        use crate::runtime::kernels_simd::detected;
        let be = NativeBackend::with_variant(KernelVariant::Avx2);
        assert!(be.kernel_variant() <= detected(), "clamp must never exceed detection");
        let batch = synth_packed_batch();
        let params = be.init_params(7);
        let z = be.infer(&params, &batch).unwrap();
        assert_eq!(z.len(), batch.n_graphs());
        assert!(z.iter().all(|v| v.is_finite()));
        if be.kernel_variant() == KernelVariant::Scalar {
            let scalar = NativeBackend::new();
            assert_eq!(z, scalar.infer(&params, &batch).unwrap());
        }
        #[cfg(not(feature = "simd"))]
        assert_eq!(be.kernel_variant(), KernelVariant::Scalar);
    }

    /// Int8 envelope: per-channel weight quantization stays within the
    /// declared log-runtime tolerance of the f32 reference, and a
    /// layer-count mismatch is rejected instead of misindexing.
    #[test]
    fn int8_inference_stays_within_declared_envelope() {
        use crate::runtime::quant::{INT8_Z_ABS_TOL, INT8_Z_REL_TOL};
        let be = NativeBackend::new();
        let batch = synth_packed_batch();
        let params = be.init_params(13);
        let qp = QuantParams::from_params(&params, be.manifest().n_conv).unwrap();
        let zf = be.infer(&params, &batch).unwrap();
        let zq = be.infer_quant(&qp, &batch).unwrap();
        assert_eq!(zf.len(), zq.len());
        for (i, (a, b)) in zq.iter().zip(&zf).enumerate() {
            let tol = INT8_Z_ABS_TOL + INT8_Z_REL_TOL * (b.abs() as f64);
            assert!(
                ((a - b).abs() as f64) <= tol,
                "int8 z[{i}] = {a} diverges from f32 {b} (tol {tol})"
            );
        }
        let be0 = NativeBackend::with_layers(0);
        assert!(be0.infer_quant(&qp, &batch).is_err(), "layer mismatch must be rejected");
    }

    /// The int8 path is chunk-invariant like the f32 path (block-diagonal
    /// layout, fixed per-row accumulation order), so the node-balanced
    /// parallel fan-out must reproduce sequential chunking bitwise.
    #[test]
    fn predict_runtimes_quant_matches_sequential() {
        let be = NativeBackend::new();
        let samples: Vec<GraphSample> = (0..70)
            .map(|i| synth_sample((i / 10) as u32, (i % 10) as u32, 1e-3 * (1.0 + i as f32)))
            .collect();
        let refs: Vec<&GraphSample> = samples.iter().collect();
        let stats = identity_stats();
        let params = be.init_params(11);
        let qp = QuantParams::from_params(&params, be.manifest().n_conv).unwrap();
        let parallel = be.predict_runtimes_quant(&qp, &refs, &stats).unwrap();
        assert_eq!(parallel.len(), 70);
        let mut sequential = Vec::new();
        for chunk in refs.chunks(BATCH) {
            let batch = PackedBatch::for_inference(chunk, &stats).unwrap();
            let z = be.infer_quant(&qp, &batch).unwrap();
            sequential.extend(z.iter().map(|&v| (v as f64).exp()));
        }
        assert_eq!(parallel, sequential);
        assert!(parallel.iter().all(|p| p.is_finite() && *p > 0.0));
    }
}
