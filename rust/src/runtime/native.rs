//! Pure-Rust GCN execution engine — the default [`Backend`].
//!
//! Implements the paper's model (Fig 7) with the exact artifact semantics
//! of `python/compile/aot.py` / `python/compile/model.py`:
//!
//! * forward: Fig 5 dual feature embedding → `n_conv` graph convolutions
//!   (Kipf–Welling aggregate-update `A' · (E · W) + b`, per-node channel
//!   normalization, ReLU) → masked sum-pool readout per conv level →
//!   linear head predicting log-runtime `z` (one value per graph);
//! * train: the §III-C weighted relative-error loss
//!   `ξ = |exp(z − log ȳ) − 1|` (linearized beyond `|d| = 3`), analytic
//!   backprop through the whole network, and an Adagrad step with weight
//!   decay — semantically identical to `model.train_step`.
//!
//! Tensor math accumulates in `f64` and stores `f32` at the same op
//! boundaries as the JAX model, so outputs match the dependency-free
//! reference (`python/compile/kernels/ref.py`) to ≤1e-5; the parity tests
//! below pin that against JAX-generated reference numbers.
//!
//! [`Backend::predict_runtimes`] is overridden to fan batch chunks out
//! over [`crate::util::threadpool`], which is what lets beam search and
//! the eval harnesses amortize model queries across cores.

use crate::constants::{
    ADAGRAD_EPS, BATCH, DEP_DIM, EMB_DEP, EMB_INV, INV_DIM, MAX_NODES, NODE_DIM, N_CONV,
};
use crate::dataset::sample::GraphSample;
use crate::features::normalize::FeatureStats;
use crate::model::Batch;
use crate::runtime::backend::{predict_chunk, Backend};
use crate::runtime::manifest::Manifest;
use crate::runtime::params::Params;
use anyhow::{ensure, Result};

// The conv math below indexes weight tensors of manifest shape
// [HIDDEN, HIDDEN] with NODE_DIM strides; that is only sound while the
// conv width equals the node embedding width (true in the paper's model).
const _: () = assert!(
    crate::constants::HIDDEN == NODE_DIM,
    "native backend assumes HIDDEN == NODE_DIM (conv width == embedding width)"
);

/// Channel-normalization epsilon (`graph_batch_norm` in `model.py`).
const LN_EPS: f64 = 1e-5;
/// Loss linearization point: ξ switches to a linear tail beyond |d| = 3.
const LOSS_CLIP: f64 = 3.0;

/// The native engine. Stateless apart from its manifest; cheap to build
/// and `Sync`, so inference parallelizes freely.
pub struct NativeBackend {
    manifest: Manifest,
}

impl Default for NativeBackend {
    fn default() -> Self {
        NativeBackend::new()
    }
}

impl NativeBackend {
    /// The paper's configuration: two graph-convolution layers.
    pub fn new() -> NativeBackend {
        NativeBackend::with_layers(N_CONV)
    }

    /// A conv-depth ablation variant (§III-C sweep: 0/1/2/4 layers).
    pub fn with_layers(n_conv: usize) -> NativeBackend {
        NativeBackend { manifest: Manifest::native(n_conv) }
    }

    fn n_conv(&self) -> usize {
        self.manifest.n_conv
    }

    fn readout(&self) -> usize {
        NODE_DIM * (self.n_conv() + 1)
    }

    /// Index of `w_out` in the flat parameter list (`b_out` follows it).
    fn p_w_out(&self) -> usize {
        4 + 4 * self.n_conv()
    }

    fn check_params(&self, params: &Params) -> Result<()> {
        ensure!(
            params.values.len() == self.manifest.params.len(),
            "backend expects {} param tensors, got {}",
            self.manifest.params.len(),
            params.values.len()
        );
        for (v, spec) in params.values.iter().zip(&self.manifest.params) {
            ensure!(
                v.len() == spec.numel(),
                "param '{}' has {} elements, manifest expects {}",
                spec.name,
                v.len(),
                spec.numel()
            );
        }
        Ok(())
    }

    /// Full forward pass, keeping every intermediate backprop needs.
    fn forward(&self, params: &Params, batch: &Batch) -> Forward {
        let kk = self.n_conv();
        let readout = self.readout();
        let n_elems = BATCH * MAX_NODES * NODE_DIM;

        // ---- Fig 5 embedding: e0 = relu(inv·Wi + bi) ++ relu(dep·Wd + bd),
        // masked. Padded nodes stay exactly zero (skipped entirely).
        let (w_inv, b_inv) = (&params.values[0], &params.values[1]);
        let (w_dep, b_dep) = (&params.values[2], &params.values[3]);
        let mut e0 = vec![0f32; n_elems];
        for node in 0..BATCH * MAX_NODES {
            if batch.mask[node] == 0.0 {
                continue;
            }
            let inv = &batch.inv[node * INV_DIM..(node + 1) * INV_DIM];
            let dep = &batch.dep[node * DEP_DIM..(node + 1) * DEP_DIM];
            let out = &mut e0[node * NODE_DIM..(node + 1) * NODE_DIM];
            for j in 0..EMB_INV {
                let mut acc = b_inv[j] as f64;
                for (i, &x) in inv.iter().enumerate() {
                    acc += x as f64 * w_inv[i * EMB_INV + j] as f64;
                }
                out[j] = acc.max(0.0) as f32;
            }
            for j in 0..EMB_DEP {
                let mut acc = b_dep[j] as f64;
                for (i, &x) in dep.iter().enumerate() {
                    acc += x as f64 * w_dep[i * EMB_DEP + j] as f64;
                }
                out[EMB_INV + j] = acc.max(0.0) as f32;
            }
        }

        let mut e_list = Vec::with_capacity(kk + 1);
        e_list.push(e0);
        let mut h_list = Vec::with_capacity(kk);
        let mut xhat_list = Vec::with_capacity(kk);
        let mut rstd_list = Vec::with_capacity(kk);

        // ---- graph convolutions
        for k in 0..kk {
            let w = &params.values[4 + 4 * k];
            let bvec = &params.values[5 + 4 * k];
            let scale = &params.values[6 + 4 * k];
            let shift = &params.values[7 + 4 * k];
            let e_prev = &e_list[k];

            // t = E · W per node (zero rows for padded nodes — their
            // embeddings are zero, so the product is too)
            let mut t = vec![0f32; n_elems];
            for node in 0..BATCH * MAX_NODES {
                if batch.mask[node] == 0.0 {
                    continue;
                }
                let e_row = &e_prev[node * NODE_DIM..(node + 1) * NODE_DIM];
                let mut acc = [0f64; NODE_DIM];
                for (i, &x) in e_row.iter().enumerate() {
                    if x == 0.0 {
                        continue;
                    }
                    let xf = x as f64;
                    let wrow = &w[i * NODE_DIM..(i + 1) * NODE_DIM];
                    for j in 0..NODE_DIM {
                        acc[j] += xf * wrow[j] as f64;
                    }
                }
                let t_row = &mut t[node * NODE_DIM..(node + 1) * NODE_DIM];
                for j in 0..NODE_DIM {
                    t_row[j] = acc[j] as f32;
                }
            }

            // c = A' · t + b, then per-node channel norm, ReLU, mask
            let mut h = vec![0f32; n_elems];
            let mut xhat = vec![0f32; n_elems];
            let mut rstd = vec![0f32; BATCH * MAX_NODES];
            let mut e_next = vec![0f32; n_elems];
            for b in 0..BATCH {
                for n in 0..MAX_NODES {
                    let node = b * MAX_NODES + n;
                    if batch.mask[node] == 0.0 {
                        continue;
                    }
                    let arow = &batch.adj[node * MAX_NODES..(node + 1) * MAX_NODES];
                    let mut c = [0f64; NODE_DIM];
                    for (r, &a) in arow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        let af = a as f64;
                        let t_row =
                            &t[(b * MAX_NODES + r) * NODE_DIM..(b * MAX_NODES + r + 1) * NODE_DIM];
                        for j in 0..NODE_DIM {
                            c[j] += af * t_row[j] as f64;
                        }
                    }
                    for j in 0..NODE_DIM {
                        c[j] += bvec[j] as f64;
                    }
                    let mean = c.iter().sum::<f64>() / NODE_DIM as f64;
                    let var =
                        c.iter().map(|&x| (x - mean) * (x - mean)).sum::<f64>() / NODE_DIM as f64;
                    let rs = 1.0 / (var + LN_EPS).sqrt();
                    rstd[node] = rs as f32;
                    let o = node * NODE_DIM;
                    for j in 0..NODE_DIM {
                        let xh = (c[j] - mean) * rs;
                        xhat[o + j] = xh as f32;
                        let hv = xh * scale[j] as f64 + shift[j] as f64;
                        h[o + j] = hv as f32;
                        e_next[o + j] = hv.max(0.0) as f32;
                    }
                }
            }
            h_list.push(h);
            xhat_list.push(xhat);
            rstd_list.push(rstd);
            e_list.push(e_next);
        }

        // ---- masked sum-pool readout per conv level + linear head
        let w_out = &params.values[self.p_w_out()];
        let b_out = &params.values[self.p_w_out() + 1];
        let mut feat = vec![0f32; BATCH * readout];
        let mut z = vec![0f32; BATCH];
        for b in 0..BATCH {
            for (k, e) in e_list.iter().enumerate() {
                let f_off = b * readout + k * NODE_DIM;
                for n in 0..MAX_NODES {
                    let node = b * MAX_NODES + n;
                    if batch.mask[node] == 0.0 {
                        continue;
                    }
                    let row = &e[node * NODE_DIM..(node + 1) * NODE_DIM];
                    for j in 0..NODE_DIM {
                        feat[f_off + j] += row[j];
                    }
                }
            }
            let mut acc = b_out[0] as f64;
            for r in 0..readout {
                acc += feat[b * readout + r] as f64 * w_out[r] as f64;
            }
            z[b] = acc as f32;
        }

        Forward { e: e_list, h: h_list, xhat: xhat_list, rstd: rstd_list, feat, z }
    }

    /// Analytic gradients of the §III-C loss w.r.t. every parameter
    /// (weight decay is applied later, in the Adagrad step — matching
    /// `model.train_step`).
    fn backward(
        &self,
        params: &Params,
        batch: &Batch,
        fwd: &Forward,
        dz: &[f64],
    ) -> Vec<Vec<f64>> {
        let kk = self.n_conv();
        let readout = self.readout();
        let iw = self.p_w_out();
        let w_out = &params.values[iw];
        let mut grads: Vec<Vec<f64>> =
            params.values.iter().map(|v| vec![0f64; v.len()]).collect();

        // ---- head: z = feat · w_out + b_out
        for b in 0..BATCH {
            if dz[b] == 0.0 {
                continue;
            }
            grads[iw + 1][0] += dz[b];
            for r in 0..readout {
                grads[iw][r] += fwd.feat[b * readout + r] as f64 * dz[b];
            }
        }

        // dL/de for the deepest activations: the level-kk pooled readout
        // broadcasts dz · w_out[kk·F + j] to every (real) node.
        let mut de = vec![0f64; BATCH * MAX_NODES * NODE_DIM];
        for b in 0..BATCH {
            if dz[b] == 0.0 {
                continue;
            }
            for n in 0..MAX_NODES {
                let node = b * MAX_NODES + n;
                if batch.mask[node] == 0.0 {
                    continue;
                }
                let o = node * NODE_DIM;
                for j in 0..NODE_DIM {
                    de[o + j] = dz[b] * w_out[kk * NODE_DIM + j] as f64;
                }
            }
        }

        // ---- conv layers, deepest first
        for k in (0..kk).rev() {
            let w = &params.values[4 + 4 * k];
            let scale = &params.values[6 + 4 * k];
            let h = &fwd.h[k];
            let xh = &fwd.xhat[k];
            let rstd = &fwd.rstd[k];
            let e_prev = &fwd.e[k];

            // ReLU + channel-norm backward: de -> dc (per node)
            let mut dc = vec![0f64; BATCH * MAX_NODES * NODE_DIM];
            for node in 0..BATCH * MAX_NODES {
                if batch.mask[node] == 0.0 {
                    continue;
                }
                let o = node * NODE_DIM;
                let mut dxh = [0f64; NODE_DIM];
                let mut sum1 = 0f64;
                let mut sum2 = 0f64;
                for j in 0..NODE_DIM {
                    let dh = if h[o + j] > 0.0 { de[o + j] } else { 0.0 };
                    grads[6 + 4 * k][j] += dh * xh[o + j] as f64;
                    grads[7 + 4 * k][j] += dh;
                    let dx = dh * scale[j] as f64;
                    dxh[j] = dx;
                    sum1 += dx;
                    sum2 += dx * xh[o + j] as f64;
                }
                let rs = rstd[node] as f64;
                for j in 0..NODE_DIM {
                    let v =
                        rs * (dxh[j] - (sum1 + xh[o + j] as f64 * sum2) / NODE_DIM as f64);
                    dc[o + j] = v;
                    grads[5 + 4 * k][j] += v;
                }
            }

            // dt = A'ᵀ · dc per sample, then de_prev = dt · Wᵀ and
            // dW += e_prevᵀ · dt
            let mut de_new = vec![0f64; BATCH * MAX_NODES * NODE_DIM];
            let mut dt = vec![0f64; MAX_NODES * NODE_DIM];
            for b in 0..BATCH {
                dt.iter_mut().for_each(|v| *v = 0.0);
                for r in 0..MAX_NODES {
                    let rnode = b * MAX_NODES + r;
                    if batch.mask[rnode] == 0.0 {
                        continue;
                    }
                    let o = rnode * NODE_DIM;
                    let arow = &batch.adj[rnode * MAX_NODES..(rnode + 1) * MAX_NODES];
                    for (c_ix, &a) in arow.iter().enumerate() {
                        if a == 0.0 {
                            continue;
                        }
                        let af = a as f64;
                        let trow = &mut dt[c_ix * NODE_DIM..(c_ix + 1) * NODE_DIM];
                        for j in 0..NODE_DIM {
                            trow[j] += af * dc[o + j];
                        }
                    }
                }
                for n in 0..MAX_NODES {
                    let node = b * MAX_NODES + n;
                    if batch.mask[node] == 0.0 {
                        continue;
                    }
                    let dtrow = &dt[n * NODE_DIM..(n + 1) * NODE_DIM];
                    let erow = &e_prev[node * NODE_DIM..(node + 1) * NODE_DIM];
                    let o = node * NODE_DIM;
                    for i in 0..NODE_DIM {
                        let wrow = &w[i * NODE_DIM..(i + 1) * NODE_DIM];
                        let mut acc = 0f64;
                        for j in 0..NODE_DIM {
                            acc += dtrow[j] * wrow[j] as f64;
                        }
                        de_new[o + i] = acc;
                        let ev = erow[i] as f64;
                        if ev != 0.0 {
                            let gw = &mut grads[4 + 4 * k][i * NODE_DIM..(i + 1) * NODE_DIM];
                            for j in 0..NODE_DIM {
                                gw[j] += ev * dtrow[j];
                            }
                        }
                    }
                }
            }

            // pooled-readout gradient for level k
            for b in 0..BATCH {
                if dz[b] == 0.0 {
                    continue;
                }
                for n in 0..MAX_NODES {
                    let node = b * MAX_NODES + n;
                    if batch.mask[node] == 0.0 {
                        continue;
                    }
                    let o = node * NODE_DIM;
                    for j in 0..NODE_DIM {
                        de_new[o + j] += dz[b] * w_out[k * NODE_DIM + j] as f64;
                    }
                }
            }
            de = de_new;
        }

        // ---- embedding backward
        let e0 = &fwd.e[0];
        for node in 0..BATCH * MAX_NODES {
            if batch.mask[node] == 0.0 {
                continue;
            }
            let o = node * NODE_DIM;
            let inv = &batch.inv[node * INV_DIM..(node + 1) * INV_DIM];
            let dep = &batch.dep[node * DEP_DIM..(node + 1) * DEP_DIM];
            for j in 0..EMB_INV {
                if e0[o + j] <= 0.0 {
                    continue;
                }
                let g = de[o + j];
                if g == 0.0 {
                    continue;
                }
                grads[1][j] += g;
                for (i, &x) in inv.iter().enumerate() {
                    grads[0][i * EMB_INV + j] += x as f64 * g;
                }
            }
            for j in 0..EMB_DEP {
                if e0[o + EMB_INV + j] <= 0.0 {
                    continue;
                }
                let g = de[o + EMB_INV + j];
                if g == 0.0 {
                    continue;
                }
                grads[3][j] += g;
                for (i, &x) in dep.iter().enumerate() {
                    grads[2][i * EMB_DEP + j] += x as f64 * g;
                }
            }
        }

        grads
    }
}

/// Forward intermediates kept for the backward pass.
struct Forward {
    /// Masked node activations per level: `e[k]` for k = 0..=n_conv,
    /// each flat `BATCH · MAX_NODES · NODE_DIM`.
    e: Vec<Vec<f32>>,
    /// Post-norm pre-ReLU activations per conv layer.
    h: Vec<Vec<f32>>,
    /// Normalized (pre scale/shift) activations per conv layer.
    xhat: Vec<Vec<f32>>,
    /// Reciprocal std per node per conv layer, flat `BATCH · MAX_NODES`.
    rstd: Vec<Vec<f32>>,
    /// Pooled readout features, flat `BATCH · READOUT`.
    feat: Vec<f32>,
    /// Predicted log-runtime per graph.
    z: Vec<f32>,
}

/// §III-C loss and its gradient w.r.t. z.
///
/// `ξ = |expm1(clamp(d, ±3))| + |d − clamp(d, ±3)|·e³` with
/// `d = z − log ȳ`; the loss is the `weight·sample_mask`-weighted mean.
fn loss_and_dz(z: &[f32], batch: &Batch) -> (f64, Vec<f64>) {
    let e3 = LOSS_CLIP.exp();
    let mut wsum = 0f64;
    for b in 0..BATCH {
        wsum += (batch.weight[b] * batch.sample_mask[b]) as f64;
    }
    let denom = wsum.max(1e-6);
    let mut loss = 0f64;
    let mut dz = vec![0f64; BATCH];
    for b in 0..BATCH {
        let w = (batch.weight[b] * batch.sample_mask[b]) as f64;
        if w == 0.0 {
            continue;
        }
        let d = z[b] as f64 - batch.log_y[b] as f64;
        let dclamped = d.clamp(-LOSS_CLIP, LOSS_CLIP);
        let xi = dclamped.exp_m1().abs() + (d - dclamped).abs() * e3;
        loss += w * xi;
        let g = if d > LOSS_CLIP {
            e3
        } else if d < -LOSS_CLIP {
            -e3
        } else if d > 0.0 {
            d.exp()
        } else if d < 0.0 {
            -d.exp()
        } else {
            0.0
        };
        dz[b] = w * g / denom;
    }
    (loss / denom, dz)
}

/// Adagrad with weight decay: `g += wd·p; a += g²; p −= lr·g/(√a + ε)`.
fn apply_adagrad(params: &mut Params, accum: &mut Params, grads: &[Vec<f64>], lr: f64, wd: f64) {
    for (t, g) in grads.iter().enumerate() {
        let pv = &mut params.values[t];
        let av = &mut accum.values[t];
        for i in 0..g.len() {
            let gi = g[i] + wd * pv[i] as f64;
            let a = av[i] as f64 + gi * gi;
            av[i] = a as f32;
            pv[i] = (pv[i] as f64 - lr * gi / (a.sqrt() + ADAGRAD_EPS)) as f32;
        }
    }
}

impl Backend for NativeBackend {
    fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    fn name(&self) -> &'static str {
        "native"
    }

    fn infer(&self, params: &Params, batch: &Batch) -> Result<Vec<f32>> {
        self.check_params(params)?;
        let fwd = self.forward(params, batch);
        Ok(fwd.z[..batch.len].to_vec())
    }

    fn train_step_lr(
        &self,
        params: &mut Params,
        accum: &mut Params,
        batch: &Batch,
        lr: f32,
    ) -> Result<f32> {
        self.check_params(params)?;
        self.check_params(accum)?;
        let fwd = self.forward(params, batch);
        let (loss, dz) = loss_and_dz(&fwd.z, batch);
        let grads = self.backward(params, batch, &fwd, &dz);
        apply_adagrad(params, accum, &grads, lr as f64, self.manifest.weight_decay);
        Ok(loss as f32)
    }

    /// Parallel over batch chunks: each worker builds its padded batch and
    /// runs the forward pass independently (the backend is stateless).
    /// Every chunk goes through the same [`predict_chunk`] helper as the
    /// sequential trait default.
    fn predict_runtimes(
        &self,
        params: &Params,
        samples: &[&GraphSample],
        stats: &FeatureStats,
    ) -> Result<Vec<f64>> {
        self.check_params(params)?;
        let chunks: Vec<&[&GraphSample]> = samples.chunks(BATCH).collect();
        let outs = crate::util::threadpool::parallel_map(&chunks, |chunk| {
            predict_chunk(self, params, chunk, stats)
        });
        let mut out = Vec::with_capacity(samples.len());
        for r in outs {
            out.extend(r?);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constants::BENCH_RUNS;

    /// Deterministic integer-pattern fill shared with the JAX reference
    /// generator (see the fixture description in DESIGN.md §Testing):
    /// `h = (i·mul + add) mod m; v = (h − sub) / div` in f32.
    fn pat(i: usize, mul: u64, add: u64, m: u64, sub: f32, div: f32) -> f32 {
        let h = ((i as u64) * mul + add) % m;
        (h as f32 - sub) / div
    }

    /// The parity fixture: patterned features/adjacency, sample `b` has
    /// `3 + (7b mod 45)` real stages.
    fn parity_batch() -> Batch {
        let n = MAX_NODES;
        let mut b = Batch {
            inv: vec![0.0; BATCH * n * INV_DIM],
            dep: vec![0.0; BATCH * n * DEP_DIM],
            adj: vec![0.0; BATCH * n * n],
            mask: vec![0.0; BATCH * n],
            log_y: vec![0.0; BATCH],
            weight: vec![0.0; BATCH],
            sample_mask: vec![0.0; BATCH],
            len: BATCH,
        };
        for (i, v) in b.inv.iter_mut().enumerate() {
            *v = pat(i, 131, 7, 997, 498.0, 997.0);
        }
        for (i, v) in b.dep.iter_mut().enumerate() {
            *v = pat(i, 131, 307, 997, 498.0, 997.0);
        }
        for (i, v) in b.adj.iter_mut().enumerate() {
            *v = pat(i, 89, 3, 512, 0.0, 24576.0);
        }
        for bb in 0..BATCH {
            let real = 3 + (7 * bb) % 45;
            for nn in 0..real {
                b.mask[bb * n + nn] = 1.0;
            }
        }
        b
    }

    /// Patterned parameters matching the JAX reference generator.
    fn parity_params(manifest: &Manifest) -> Params {
        let mut values = Vec::new();
        let mut shapes = Vec::new();
        let mut names = Vec::new();
        for (ti, spec) in manifest.params.iter().enumerate() {
            let v: Vec<f32> = (0..spec.numel())
                .map(|i| {
                    let h = ((ti as u64) * 1009 + (i as u64) * 193) % 1013;
                    let base = (h as f32 - 506.0) / 1013.0;
                    if spec.name == "w_out" {
                        base * 0.05
                    } else if spec.name.ends_with("_scale") {
                        1.0 + base * 0.25
                    } else {
                        base * 0.25
                    }
                })
                .collect();
            values.push(v);
            shapes.push(spec.shape.clone());
            names.push(spec.name.clone());
        }
        Params { values, shapes, names }
    }

    /// z for the parity fixture, computed by the repo's JAX model with
    /// `use_pallas=False` (i.e. through `python/compile/kernels/ref.py`).
    const REF_Z: [f32; 32] = [
        -2.058540821e0,
        -6.377158165e0,
        -9.944972038e0,
        -1.221917439e1,
        -1.431323147e1,
        -1.581014824e1,
        -1.778214264e1,
        -4.756258011e0,
        -8.321274757e0,
        -1.084673595e1,
        -1.295297146e1,
        -1.504773235e1,
        -1.781664848e1,
        -2.804502487e0,
        -7.006120682e0,
        -9.869874001e0,
        -1.217363834e1,
        -1.442363739e1,
        -1.650897217e1,
        -1.865101242e1,
        -5.215301991e0,
        -8.816872597e0,
        -1.120118141e1,
        -1.382463169e1,
        -1.543310452e1,
        -1.775400925e1,
        -3.412985563e0,
        -7.477596760e0,
        -1.036118412e1,
        -1.242816830e1,
        -1.427667713e1,
        -1.616724014e1,
    ];

    #[test]
    fn forward_matches_jax_reference() {
        let be = NativeBackend::new();
        let batch = parity_batch();
        let params = parity_params(be.manifest());
        let z = be.infer(&params, &batch).unwrap();
        assert_eq!(z.len(), BATCH);
        for (i, (&got, &want)) in z.iter().zip(REF_Z.iter()).enumerate() {
            assert!(
                (got - want).abs() <= 1e-5,
                "z[{i}] = {got}, reference {want} (|diff| = {})",
                (got - want).abs()
            );
        }
    }

    /// Targets for the gradient parity test (same fixture + these labels).
    fn grad_fixture_batch() -> Batch {
        let mut b = parity_batch();
        for i in 0..BATCH {
            b.log_y[i] = -11.0 + (((i * 5) % 13) as f32) * 1.3;
            b.weight[i] = 0.4 + (((i * 7) % 9) as f32) * 0.11;
            b.sample_mask[i] = if i >= 30 { 0.0 } else { 1.0 };
        }
        b
    }

    /// Selected `jax.grad(model.loss_fn)` entries for the gradient fixture:
    /// (tensor index, element index, reference value).
    const REF_GRADS: [(usize, usize, f64); 13] = [
        (0, 100, -7.715898752e-2),  // w_inv
        (1, 3, 6.745553493e0),      // b_inv
        (2, 500, -2.495915815e-2),  // w_dep
        (3, 17, 5.561747551e0),     // b_dep
        (4, 321, 1.312017292e-1),   // conv0_w
        (5, 44, -1.284459591e0),    // conv0_b
        (6, 10, -5.948795319e1),    // conv0_scale
        (7, 77, -1.478031921e1),    // conv0_shift
        (8, 1234, -3.098664856e1),  // conv1_w
        (10, 63, 2.591241002e-1),   // conv1_scale
        (12, 100, -5.401177979e2),  // w_out
        (12, 239, 0.0),             // w_out — ReLU-dead readout channel
        (13, 0, -1.414331627e1),    // b_out
    ];

    const REF_LOSS: f64 = 1.421302185e2;

    #[test]
    fn backward_matches_jax_grads() {
        let be = NativeBackend::new();
        let batch = grad_fixture_batch();
        let params = parity_params(be.manifest());
        let fwd = be.forward(&params, &batch);
        let (loss, dz) = loss_and_dz(&fwd.z, &batch);
        assert!(
            (loss - REF_LOSS).abs() < 5e-3,
            "loss {loss} vs jax reference {REF_LOSS}"
        );
        let grads = be.backward(&params, &batch, &fwd, &dz);
        for &(t, i, want) in REF_GRADS.iter() {
            let got = grads[t][i];
            let tol = 1e-3 + 2e-3 * want.abs();
            assert!(
                (got - want).abs() <= tol,
                "grad[{t}][{i}] = {got}, jax reference {want} (tol {tol})"
            );
        }
    }

    fn synth_sample(pid: u32, sid: u32, runtime: f32) -> GraphSample {
        let ns = (4 + (pid as usize + sid as usize) % 5) as u16;
        let n = ns as usize;
        let mut inv = vec![[0f32; INV_DIM]; n];
        let mut dep = vec![[0f32; DEP_DIM]; n];
        for s in 0..n {
            for j in 0..INV_DIM {
                inv[s][j] = pat(
                    (pid as usize * 97 + s) * INV_DIM + j,
                    211,
                    5,
                    883,
                    441.0,
                    441.0,
                );
            }
            for j in 0..DEP_DIM {
                dep[s][j] = pat(
                    ((pid as usize * 31 + sid as usize * 7 + s) * DEP_DIM) + j,
                    157,
                    11,
                    883,
                    441.0,
                    441.0,
                );
            }
        }
        GraphSample {
            pipeline_id: pid,
            schedule_id: sid,
            n_stages: ns,
            edges: (0..n.saturating_sub(1)).map(|i| (i as u16, (i + 1) as u16)).collect(),
            inv,
            dep,
            runs: [runtime; BENCH_RUNS],
        }
    }

    fn identity_stats() -> FeatureStats {
        FeatureStats {
            inv_mean: vec![0.0; INV_DIM],
            inv_std: vec![1.0; INV_DIM],
            dep_mean: vec![0.0; DEP_DIM],
            dep_std: vec![1.0; DEP_DIM],
        }
    }

    /// Fixed-seed synthetic batch: 4 pipelines × 8 schedules with runtimes
    /// spread ~6×, plus the per-pipeline best for the α weights.
    fn synth_batch() -> Batch {
        let mut samples = Vec::new();
        let mut best = Vec::new();
        for i in 0..BATCH {
            let pid = (i / 8) as u32;
            let sid = (i % 8) as u32;
            let base = 1e-3 * (1.0 + pid as f32);
            samples.push(synth_sample(pid, sid, base * (1.0 + 0.7 * sid as f32)));
            best.push(base as f64);
        }
        let refs: Vec<&GraphSample> = samples.iter().collect();
        Batch::build(&refs, &identity_stats(), &best)
    }

    #[test]
    fn adagrad_training_reduces_loss_over_50_steps() {
        let be = NativeBackend::new();
        let batch = synth_batch();
        // deterministic patterned init (the JAX simulation of this exact
        // fixture converges 6.06 -> 0.33 in 50 steps at lr 0.01)
        let mut params = parity_params(be.manifest());
        // output-bias init at the batch mean log-runtime (as train() does)
        let mean_log_y: f32 = batch.log_y.iter().sum::<f32>() / BATCH as f32;
        params.values.last_mut().unwrap()[0] = mean_log_y;
        let mut accum = params.zeros_like();
        let mut losses = Vec::with_capacity(50);
        for _ in 0..50 {
            let l = be.train_step_lr(&mut params, &mut accum, &batch, 0.01).unwrap();
            assert!(l.is_finite(), "loss must stay finite");
            losses.push(l);
        }
        assert!(
            losses[49] < losses[0],
            "50 Adagrad steps must reduce the loss: {} -> {}",
            losses[0],
            losses[49]
        );
        // and decisively so on a memorizable single batch
        assert!(
            losses[49] < losses[0] * 0.5,
            "expected >2x loss reduction: {} -> {}",
            losses[0],
            losses[49]
        );
    }

    #[test]
    fn infer_is_deterministic_and_masks_padding() {
        let be = NativeBackend::new();
        let samples: Vec<GraphSample> =
            (0..5).map(|i| synth_sample(0, i, 1e-3 * (1.0 + i as f32))).collect();
        let refs: Vec<&GraphSample> = samples.iter().collect();
        let best = vec![1e-3f64; refs.len()];
        let clean = Batch::build(&refs, &identity_stats(), &best);
        let params = be.init_params(3);
        let z1 = be.infer(&params, &clean).unwrap();
        let z2 = be.infer(&params, &clean).unwrap();
        assert_eq!(z1.len(), 5);
        assert_eq!(z1, z2);
        assert!(z1.iter().all(|v| v.is_finite()));

        // poisoning the padded region must not change predictions
        let mut poisoned = clean.clone();
        let n = MAX_NODES;
        for b in 5..BATCH {
            for v in &mut poisoned.inv[b * n * INV_DIM..(b + 1) * n * INV_DIM] {
                *v = 1234.5;
            }
            for v in &mut poisoned.dep[b * n * DEP_DIM..(b + 1) * n * DEP_DIM] {
                *v = -77.7;
            }
        }
        let z3 = be.infer(&params, &poisoned).unwrap();
        assert_eq!(z1, z3, "padding rows leaked into predictions");
    }

    #[test]
    fn predict_runtimes_parallel_matches_sequential() {
        let be = NativeBackend::new();
        let samples: Vec<GraphSample> = (0..70)
            .map(|i| synth_sample((i / 10) as u32, (i % 10) as u32, 1e-3 * (1.0 + i as f32)))
            .collect();
        let refs: Vec<&GraphSample> = samples.iter().collect();
        let stats = identity_stats();
        let params = be.init_params(11);
        let parallel = be.predict_runtimes(&params, &refs, &stats).unwrap();
        assert_eq!(parallel.len(), 70);

        // sequential reference: one padded batch per chunk
        let mut sequential = Vec::new();
        for chunk in refs.chunks(BATCH) {
            let best = vec![1.0f64; chunk.len()];
            let batch = Batch::build(chunk, &stats, &best);
            let z = be.infer(&params, &batch).unwrap();
            sequential.extend(z.iter().map(|&v| (v as f64).exp()));
        }
        assert_eq!(parallel, sequential);
        assert!(parallel.iter().all(|p| p.is_finite() && *p > 0.0));
    }

    #[test]
    fn ablation_depths_run_natively() {
        for layers in [0usize, 1, 4] {
            let be = NativeBackend::with_layers(layers);
            assert_eq!(be.manifest().params.len(), 6 + 4 * layers);
            let batch = synth_batch();
            let params = be.init_params(5);
            let z = be.infer(&params, &batch).unwrap();
            assert_eq!(z.len(), BATCH);
            assert!(z.iter().all(|v| v.is_finite()));
            let mut p = params.clone();
            let mut a = p.zeros_like();
            let l = be.train_step_lr(&mut p, &mut a, &batch, 0.01).unwrap();
            assert!(l.is_finite());
        }
    }

    #[test]
    fn check_params_rejects_wrong_layout() {
        let be = NativeBackend::new();
        let wrong = be.init_params(1);
        let be0 = NativeBackend::with_layers(0);
        let batch = synth_batch();
        assert!(be0.infer(&wrong, &batch).is_err());
    }
}
