//! SIMD microkernel layer over [`crate::runtime::kernels`], plus the
//! int8 row kernels the quantized inference path runs on.
//!
//! The scalar kernels in `kernels.rs` stay the always-compiled,
//! bitwise-deterministic reference; everything here is an opt-in numeric
//! mode behind the `simd` cargo feature:
//!
//! * [`KernelVariant`] names the three tiers (`scalar`/`sse2`/`avx2`).
//!   [`detected`] picks the best tier the running CPU supports, once,
//!   via `is_x86_feature_detected!`; a build without the `simd` feature
//!   (or off x86_64) always detects `Scalar`. The `GCN_PERF_KERNELS`
//!   environment variable can clamp the choice *down* (e.g. `scalar` to
//!   A/B a machine) — requests above the CPU's capability are clamped by
//!   [`resolve`], never trusted, because running an AVX2 kernel on a
//!   non-AVX2 CPU would be undefined behavior.
//! * The `_v` dispatchers ([`accumulate_tiled_v`], [`embed_row_v`],
//!   [`gemm_row_v`], [`conv_row_infer_v`], [`qlinear_row_v`]) route one
//!   row of work to the chosen tier. They are what the native engine's
//!   inference fast path calls; the training forward keeps calling the
//!   scalar kernels directly, so train/autotune-checkpoint/loadgen
//!   verification stay bitwise-reproducible regardless of build flags.
//!
//! **Numeric-mode contract.** The engine accumulates in f64 from f32
//! inputs, so every product of two f32-derived f64 values is exact
//! (≤ 48 significand bits); the AVX2/SSE2 f64 kernels vectorize over the
//! *output* index `j` while keeping each output's ascending-`i` chain,
//! so in practice they reproduce the scalar chain exactly. The declared
//! contract is nevertheless a tolerance envelope, not bitwise:
//! per-output agreement within [`SIMD_REL_TOL`] relative, plus the
//! end-to-end zoo prediction-error/ranking bound `eval::simd_bench`
//! enforces. The int8 kernels ([`qlinear_row`]) accumulate in f32
//! against per-output-channel scales and are validated only under the
//! (larger) quantization envelope in `runtime::quant`.

use crate::model::PackedBatch;
use crate::runtime::kernels;
use std::sync::OnceLock;

/// Per-output relative tolerance of the SIMD f64 kernels against the
/// scalar reference (the declared envelope; in practice they agree
/// bitwise — see the module docs).
pub const SIMD_REL_TOL: f64 = 1e-5;

/// The kernel tiers runtime dispatch can select. Ordering is capability
/// order: `Scalar < Sse2 < Avx2`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum KernelVariant {
    /// The always-compiled reference kernels (bitwise-deterministic).
    Scalar,
    /// 2-lane f64 SSE2 kernels (x86_64 baseline; no FMA).
    Sse2,
    /// 4-lane f64 / 8-lane f32 AVX2+FMA kernels.
    Avx2,
}

impl KernelVariant {
    pub fn as_str(&self) -> &'static str {
        match self {
            KernelVariant::Scalar => "scalar",
            KernelVariant::Sse2 => "sse2",
            KernelVariant::Avx2 => "avx2",
        }
    }

    pub fn parse(s: &str) -> Option<KernelVariant> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(KernelVariant::Scalar),
            "sse2" => Some(KernelVariant::Sse2),
            "avx2" => Some(KernelVariant::Avx2),
            _ => None,
        }
    }
}

/// Clamp a requested variant to what this build + CPU can actually run.
/// Requests at or below `available` are honored (forcing *down* is how
/// scalar-vs-SIMD A/B runs work); requests above it fall back.
pub fn resolve(available: KernelVariant, requested: KernelVariant) -> KernelVariant {
    if requested <= available {
        requested
    } else {
        available
    }
}

fn hardware_best() -> KernelVariant {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    {
        if is_x86_feature_detected!("avx2") && is_x86_feature_detected!("fma") {
            return KernelVariant::Avx2;
        }
        if is_x86_feature_detected!("sse2") {
            return KernelVariant::Sse2;
        }
    }
    KernelVariant::Scalar
}

/// The best variant this process can run, detected once. Honors a
/// `GCN_PERF_KERNELS` environment override, clamped down to the CPU's
/// capability (an unparseable value is ignored).
pub fn detected() -> KernelVariant {
    static DETECTED: OnceLock<KernelVariant> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        let hw = hardware_best();
        match std::env::var("GCN_PERF_KERNELS").ok().and_then(|v| KernelVariant::parse(&v)) {
            Some(requested) => resolve(hw, requested),
            None => hw,
        }
    })
}

// ------------------------------------------------------------ dispatch
//
// Callers must pass a variant already clamped through `resolve`/
// `detected` (the native engine's constructors do); the SIMD arms are
// `unsafe` precisely because the target features must be present.

/// `acc[j] += Σ_i x[i] · w[i·m + j]` on the chosen tier.
pub(crate) fn accumulate_tiled_v(
    v: KernelVariant,
    x: &[f32],
    w: &[f32],
    m: usize,
    acc: &mut [f64],
) {
    match v {
        KernelVariant::Scalar => kernels::accumulate_tiled(x, w, m, acc),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: callers only pass Sse2/Avx2 after `detected()` proved
        // the CPU supports them.
        KernelVariant::Sse2 => unsafe { sse2::accumulate_tiled(x, w, m, acc) },
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: as above.
        KernelVariant::Avx2 => unsafe { avx2::accumulate_tiled(x, w, m, acc) },
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        _ => kernels::accumulate_tiled(x, w, m, acc),
    }
}

/// Fig 5 dual embedding for one node on the chosen tier.
#[allow(clippy::too_many_arguments)]
pub(crate) fn embed_row_v(
    v: KernelVariant,
    inv: &[f32],
    dep: &[f32],
    w_inv: &[f32],
    b_inv: &[f32],
    w_dep: &[f32],
    b_dep: &[f32],
    out: &mut [f32],
) {
    match v {
        KernelVariant::Scalar => kernels::embed_row(inv, dep, w_inv, b_inv, w_dep, b_dep, out),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: variant is CPU-verified by the caller (see above).
        KernelVariant::Sse2 => unsafe {
            sse2::embed_row(inv, dep, w_inv, b_inv, w_dep, b_dep, out)
        },
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: as above.
        KernelVariant::Avx2 => unsafe {
            avx2::embed_row(inv, dep, w_inv, b_inv, w_dep, b_dep, out)
        },
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        _ => kernels::embed_row(inv, dep, w_inv, b_inv, w_dep, b_dep, out),
    }
}

/// One row of the conv projection `t = E · W` on the chosen tier.
pub(crate) fn gemm_row_v(v: KernelVariant, e_row: &[f32], w: &[f32], out: &mut [f32]) {
    match v {
        KernelVariant::Scalar => kernels::gemm_row(e_row, w, out),
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: variant is CPU-verified by the caller (see above).
        KernelVariant::Sse2 => unsafe { sse2::gemm_row(e_row, w, out) },
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: as above.
        KernelVariant::Avx2 => unsafe { avx2::gemm_row(e_row, w, out) },
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        _ => kernels::gemm_row(e_row, w, out),
    }
}

/// Fused inference conv row (gather + bias + norm + scale/shift + ReLU)
/// on the chosen tier. The channel-norm statistics stay scalar f64 on
/// every tier — only the O(E) gather is vectorized.
#[allow(clippy::too_many_arguments)]
pub(crate) fn conv_row_infer_v(
    v: KernelVariant,
    batch: &PackedBatch,
    t: &[f32],
    node: usize,
    bvec: &[f32],
    scale: &[f32],
    shift: &[f32],
    e_next: &mut [f32],
) {
    match v {
        KernelVariant::Scalar => {
            kernels::conv_row_infer(batch, t, node, bvec, scale, shift, e_next)
        }
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: variant is CPU-verified by the caller (see above).
        KernelVariant::Sse2 => unsafe {
            sse2::conv_row_infer(batch, t, node, bvec, scale, shift, e_next)
        },
        #[cfg(all(feature = "simd", target_arch = "x86_64"))]
        // SAFETY: as above.
        KernelVariant::Avx2 => unsafe {
            avx2::conv_row_infer(batch, t, node, bvec, scale, shift, e_next)
        },
        #[cfg(not(all(feature = "simd", target_arch = "x86_64")))]
        _ => kernels::conv_row_infer(batch, t, node, bvec, scale, shift, e_next),
    }
}

// ---------------------------------------------------------- int8 rows

/// One int8 linear row, the quantized path's workhorse:
/// `out[j] = maybe_relu(scale[j] · Σ_i x[i] · q[i·n_out + j] + bias[j])`
/// with f32 accumulation (`out` doubles as the accumulator, so the call
/// allocates nothing). This scalar form is the always-compiled
/// reference for the vectorized tiers.
pub(crate) fn qlinear_row(
    x: &[f32],
    q: &[i8],
    scale: &[f32],
    bias: Option<&[f32]>,
    relu: bool,
    out: &mut [f32],
) {
    let n_out = out.len();
    debug_assert_eq!(q.len(), x.len() * n_out);
    debug_assert_eq!(scale.len(), n_out);
    for o in out.iter_mut() {
        *o = 0.0;
    }
    for (i, &xv) in x.iter().enumerate() {
        if xv == 0.0 {
            continue;
        }
        let qrow = &q[i * n_out..(i + 1) * n_out];
        for j in 0..n_out {
            out[j] += xv * qrow[j] as f32;
        }
    }
    for j in 0..n_out {
        let mut v = out[j] * scale[j];
        if let Some(b) = bias {
            v += b[j];
        }
        if relu {
            v = v.max(0.0);
        }
        out[j] = v;
    }
}

/// [`qlinear_row`] on the chosen tier (SSE2 has no useful int8→f32
/// widening story at 2 lanes, so it shares the scalar row).
pub(crate) fn qlinear_row_v(
    v: KernelVariant,
    x: &[f32],
    q: &[i8],
    scale: &[f32],
    bias: Option<&[f32]>,
    relu: bool,
    out: &mut [f32],
) {
    #[cfg(all(feature = "simd", target_arch = "x86_64"))]
    if v == KernelVariant::Avx2 {
        // SAFETY: variant is CPU-verified by the caller (see above).
        return unsafe { avx2::qlinear_row(x, q, scale, bias, relu, out) };
    }
    let _ = v;
    qlinear_row(x, q, scale, bias, relu, out)
}

// ------------------------------------------------------------- kernels

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod sse2 {
    //! 2-lane f64 kernels (x86_64 baseline). A product of two
    //! f32-derived f64 values is exact, so mul-then-add reproduces the
    //! scalar rounding per step; lanes cover distinct outputs `j`, so
    //! the per-output chain is unchanged.

    use crate::constants::{EMB_DEP, EMB_INV, NODE_DIM};
    use crate::model::PackedBatch;
    use crate::runtime::kernels;
    use std::arch::x86_64::*;

    /// Load exactly two f32s (8 bytes) into the low lanes.
    #[inline]
    unsafe fn load2(p: *const f32) -> __m128 {
        _mm_castsi128_ps(_mm_loadl_epi64(p as *const __m128i))
    }

    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn accumulate_tiled(x: &[f32], w: &[f32], m: usize, acc: &mut [f64]) {
        debug_assert_eq!(acc.len(), m);
        debug_assert_eq!(w.len(), x.len() * m);
        let main = m - m % 2;
        let mut panels = x.chunks_exact(4);
        let mut i = 0usize;
        for p in panels.by_ref() {
            if p[0] == 0.0 && p[1] == 0.0 && p[2] == 0.0 && p[3] == 0.0 {
                i += 4;
                continue;
            }
            let xv = [
                _mm_set1_pd(p[0] as f64),
                _mm_set1_pd(p[1] as f64),
                _mm_set1_pd(p[2] as f64),
                _mm_set1_pd(p[3] as f64),
            ];
            let rows = [
                w[i * m..(i + 1) * m].as_ptr(),
                w[(i + 1) * m..(i + 2) * m].as_ptr(),
                w[(i + 2) * m..(i + 3) * m].as_ptr(),
                w[(i + 3) * m..(i + 4) * m].as_ptr(),
            ];
            let mut j = 0usize;
            while j < main {
                let mut a = _mm_loadu_pd(acc.as_ptr().add(j));
                for r in 0..4 {
                    let wv = _mm_cvtps_pd(load2(rows[r].add(j)));
                    a = _mm_add_pd(a, _mm_mul_pd(xv[r], wv));
                }
                _mm_storeu_pd(acc.as_mut_ptr().add(j), a);
                j += 2;
            }
            let (x0, x1, x2, x3) = (p[0] as f64, p[1] as f64, p[2] as f64, p[3] as f64);
            for j in main..m {
                let mut a = acc[j];
                a += x0 * *rows[0].add(j) as f64;
                a += x1 * *rows[1].add(j) as f64;
                a += x2 * *rows[2].add(j) as f64;
                a += x3 * *rows[3].add(j) as f64;
                acc[j] = a;
            }
            i += 4;
        }
        for &xs in panels.remainder() {
            if xs != 0.0 {
                let xf = xs as f64;
                let xb = _mm_set1_pd(xf);
                let wrow = w[i * m..(i + 1) * m].as_ptr();
                let mut j = 0usize;
                while j < main {
                    let a = _mm_loadu_pd(acc.as_ptr().add(j));
                    let wv = _mm_cvtps_pd(load2(wrow.add(j)));
                    _mm_storeu_pd(acc.as_mut_ptr().add(j), _mm_add_pd(a, _mm_mul_pd(xb, wv)));
                    j += 2;
                }
                for j in main..m {
                    acc[j] += xf * *wrow.add(j) as f64;
                }
            }
            i += 1;
        }
    }

    #[target_feature(enable = "sse2")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn embed_row(
        inv: &[f32],
        dep: &[f32],
        w_inv: &[f32],
        b_inv: &[f32],
        w_dep: &[f32],
        b_dep: &[f32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), NODE_DIM);
        let mut acc = [0f64; NODE_DIM];
        for (a, &b) in acc[..EMB_INV].iter_mut().zip(b_inv) {
            *a = b as f64;
        }
        accumulate_tiled(inv, w_inv, EMB_INV, &mut acc[..EMB_INV]);
        for (a, &b) in acc[EMB_INV..].iter_mut().zip(b_dep) {
            *a = b as f64;
        }
        accumulate_tiled(dep, w_dep, EMB_DEP, &mut acc[EMB_INV..]);
        for (o, &a) in out.iter_mut().zip(&acc) {
            *o = a.max(0.0) as f32;
        }
    }

    #[target_feature(enable = "sse2")]
    pub(super) unsafe fn gemm_row(e_row: &[f32], w: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), NODE_DIM);
        let mut acc = [0f64; NODE_DIM];
        accumulate_tiled(e_row, w, NODE_DIM, &mut acc);
        for (o, &a) in out.iter_mut().zip(&acc) {
            *o = a as f32;
        }
    }

    #[target_feature(enable = "sse2")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn conv_row_infer(
        batch: &PackedBatch,
        t: &[f32],
        node: usize,
        bvec: &[f32],
        scale: &[f32],
        shift: &[f32],
        e_next: &mut [f32],
    ) {
        let (cols, vals) = batch.adj.row(node);
        let mut c = [0f64; NODE_DIM];
        for (&cix, &a) in cols.iter().zip(vals) {
            let ab = _mm_set1_pd(a as f64);
            let t_row = t[cix as usize * NODE_DIM..(cix as usize + 1) * NODE_DIM].as_ptr();
            let mut j = 0usize;
            while j < NODE_DIM {
                let cv = _mm_loadu_pd(c.as_ptr().add(j));
                let tv = _mm_cvtps_pd(load2(t_row.add(j)));
                _mm_storeu_pd(c.as_mut_ptr().add(j), _mm_add_pd(cv, _mm_mul_pd(ab, tv)));
                j += 2;
            }
        }
        for (cj, &b) in c.iter_mut().zip(bvec) {
            *cj += b as f64;
        }
        let (mean, rs) = kernels::norm_stats(&c);
        for j in 0..NODE_DIM {
            let xh = (c[j] - mean) * rs;
            let hv = xh * scale[j] as f64 + shift[j] as f64;
            e_next[j] = hv.max(0.0) as f32;
        }
    }
}

#[cfg(all(feature = "simd", target_arch = "x86_64"))]
mod avx2 {
    //! 4-lane f64 (and 8-lane f32 for int8) AVX2+FMA kernels. FMA
    //! rounds `a·b + c` once, but `a·b` is already exact here (both
    //! factors f32-derived), so each step rounds exactly like the
    //! scalar add; lanes cover distinct outputs `j`, so the per-output
    //! chain is unchanged.

    use crate::constants::{EMB_DEP, EMB_INV, NODE_DIM};
    use crate::model::PackedBatch;
    use crate::runtime::kernels;
    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn accumulate_tiled(x: &[f32], w: &[f32], m: usize, acc: &mut [f64]) {
        debug_assert_eq!(acc.len(), m);
        debug_assert_eq!(w.len(), x.len() * m);
        let main = m - m % 4;
        let mut panels = x.chunks_exact(4);
        let mut i = 0usize;
        for p in panels.by_ref() {
            if p[0] == 0.0 && p[1] == 0.0 && p[2] == 0.0 && p[3] == 0.0 {
                i += 4;
                continue;
            }
            let xv = [
                _mm256_set1_pd(p[0] as f64),
                _mm256_set1_pd(p[1] as f64),
                _mm256_set1_pd(p[2] as f64),
                _mm256_set1_pd(p[3] as f64),
            ];
            let rows = [
                w[i * m..(i + 1) * m].as_ptr(),
                w[(i + 1) * m..(i + 2) * m].as_ptr(),
                w[(i + 2) * m..(i + 3) * m].as_ptr(),
                w[(i + 3) * m..(i + 4) * m].as_ptr(),
            ];
            let mut j = 0usize;
            while j < main {
                let mut a = _mm256_loadu_pd(acc.as_ptr().add(j));
                for r in 0..4 {
                    let wv = _mm256_cvtps_pd(_mm_loadu_ps(rows[r].add(j)));
                    a = _mm256_fmadd_pd(xv[r], wv, a);
                }
                _mm256_storeu_pd(acc.as_mut_ptr().add(j), a);
                j += 4;
            }
            let (x0, x1, x2, x3) = (p[0] as f64, p[1] as f64, p[2] as f64, p[3] as f64);
            for j in main..m {
                let mut a = acc[j];
                a += x0 * *rows[0].add(j) as f64;
                a += x1 * *rows[1].add(j) as f64;
                a += x2 * *rows[2].add(j) as f64;
                a += x3 * *rows[3].add(j) as f64;
                acc[j] = a;
            }
            i += 4;
        }
        for &xs in panels.remainder() {
            if xs != 0.0 {
                let xf = xs as f64;
                let xb = _mm256_set1_pd(xf);
                let wrow = w[i * m..(i + 1) * m].as_ptr();
                let mut j = 0usize;
                while j < main {
                    let a = _mm256_loadu_pd(acc.as_ptr().add(j));
                    let wv = _mm256_cvtps_pd(_mm_loadu_ps(wrow.add(j)));
                    _mm256_storeu_pd(acc.as_mut_ptr().add(j), _mm256_fmadd_pd(xb, wv, a));
                    j += 4;
                }
                for j in main..m {
                    acc[j] += xf * *wrow.add(j) as f64;
                }
            }
            i += 1;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn embed_row(
        inv: &[f32],
        dep: &[f32],
        w_inv: &[f32],
        b_inv: &[f32],
        w_dep: &[f32],
        b_dep: &[f32],
        out: &mut [f32],
    ) {
        debug_assert_eq!(out.len(), NODE_DIM);
        let mut acc = [0f64; NODE_DIM];
        for (a, &b) in acc[..EMB_INV].iter_mut().zip(b_inv) {
            *a = b as f64;
        }
        accumulate_tiled(inv, w_inv, EMB_INV, &mut acc[..EMB_INV]);
        for (a, &b) in acc[EMB_INV..].iter_mut().zip(b_dep) {
            *a = b as f64;
        }
        accumulate_tiled(dep, w_dep, EMB_DEP, &mut acc[EMB_INV..]);
        for (o, &a) in out.iter_mut().zip(&acc) {
            *o = a.max(0.0) as f32;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn gemm_row(e_row: &[f32], w: &[f32], out: &mut [f32]) {
        debug_assert_eq!(out.len(), NODE_DIM);
        let mut acc = [0f64; NODE_DIM];
        accumulate_tiled(e_row, w, NODE_DIM, &mut acc);
        for (o, &a) in out.iter_mut().zip(&acc) {
            *o = a as f32;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    #[allow(clippy::too_many_arguments)]
    pub(super) unsafe fn conv_row_infer(
        batch: &PackedBatch,
        t: &[f32],
        node: usize,
        bvec: &[f32],
        scale: &[f32],
        shift: &[f32],
        e_next: &mut [f32],
    ) {
        let (cols, vals) = batch.adj.row(node);
        let mut c = [0f64; NODE_DIM];
        for (&cix, &a) in cols.iter().zip(vals) {
            let ab = _mm256_set1_pd(a as f64);
            let t_row = t[cix as usize * NODE_DIM..(cix as usize + 1) * NODE_DIM].as_ptr();
            let mut j = 0usize;
            while j < NODE_DIM {
                let cv = _mm256_loadu_pd(c.as_ptr().add(j));
                let tv = _mm256_cvtps_pd(_mm_loadu_ps(t_row.add(j)));
                _mm256_storeu_pd(c.as_mut_ptr().add(j), _mm256_fmadd_pd(ab, tv, cv));
                j += 4;
            }
        }
        for (cj, &b) in c.iter_mut().zip(bvec) {
            *cj += b as f64;
        }
        let (mean, rs) = kernels::norm_stats(&c);
        for j in 0..NODE_DIM {
            let xh = (c[j] - mean) * rs;
            let hv = xh * scale[j] as f64 + shift[j] as f64;
            e_next[j] = hv.max(0.0) as f32;
        }
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub(super) unsafe fn qlinear_row(
        x: &[f32],
        q: &[i8],
        scale: &[f32],
        bias: Option<&[f32]>,
        relu: bool,
        out: &mut [f32],
    ) {
        let n_out = out.len();
        debug_assert_eq!(q.len(), x.len() * n_out);
        debug_assert_eq!(scale.len(), n_out);
        let main = n_out - n_out % 8;
        for o in out.iter_mut() {
            *o = 0.0;
        }
        for (i, &xv) in x.iter().enumerate() {
            if xv == 0.0 {
                continue;
            }
            let xb = _mm256_set1_ps(xv);
            let qrow = q[i * n_out..(i + 1) * n_out].as_ptr();
            let mut j = 0usize;
            while j < main {
                // 8 i8 weights -> i32 lanes -> f32 lanes, then FMA
                let qi = _mm_loadl_epi64(qrow.add(j) as *const __m128i);
                let qf = _mm256_cvtepi32_ps(_mm256_cvtepi8_epi32(qi));
                let ov = _mm256_loadu_ps(out.as_ptr().add(j));
                _mm256_storeu_ps(out.as_mut_ptr().add(j), _mm256_fmadd_ps(xb, qf, ov));
                j += 8;
            }
            for j in main..n_out {
                out[j] += xv * *qrow.add(j) as f32;
            }
        }
        for j in 0..n_out {
            let mut v = out[j] * scale[j];
            if let Some(b) = bias {
                v += b[j];
            }
            if relu {
                v = v.max(0.0);
            }
            out[j] = v;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constants::{DEP_DIM, EMB_DEP, EMB_INV, INV_DIM, NODE_DIM};
    use crate::util::rng::Rng;

    fn variants_up_to_detected() -> Vec<KernelVariant> {
        [KernelVariant::Scalar, KernelVariant::Sse2, KernelVariant::Avx2]
            .into_iter()
            .filter(|&v| v <= detected())
            .collect()
    }

    fn assert_close(simd: f64, scalar: f64, what: &str) {
        let tol = SIMD_REL_TOL * scalar.abs().max(1.0);
        assert!(
            (simd - scalar).abs() <= tol,
            "{what}: simd {simd} vs scalar {scalar} (tol {tol})"
        );
    }

    #[test]
    fn variant_parse_roundtrip_and_order() {
        for v in [KernelVariant::Scalar, KernelVariant::Sse2, KernelVariant::Avx2] {
            assert_eq!(KernelVariant::parse(v.as_str()), Some(v));
        }
        assert_eq!(KernelVariant::parse("AVX2"), Some(KernelVariant::Avx2));
        assert_eq!(KernelVariant::parse("neon"), None);
        assert!(KernelVariant::Scalar < KernelVariant::Sse2);
        assert!(KernelVariant::Sse2 < KernelVariant::Avx2);
    }

    #[test]
    fn resolve_clamps_up_requests_and_honors_down() {
        use KernelVariant::*;
        // forcing down is always honored (scalar A/B runs)
        assert_eq!(resolve(Avx2, Scalar), Scalar);
        assert_eq!(resolve(Avx2, Sse2), Sse2);
        assert_eq!(resolve(Sse2, Scalar), Scalar);
        // forcing up is never honored (it would be UB)
        assert_eq!(resolve(Scalar, Avx2), Scalar);
        assert_eq!(resolve(Scalar, Sse2), Scalar);
        assert_eq!(resolve(Sse2, Avx2), Sse2);
        // exact matches pass through
        for v in [Scalar, Sse2, Avx2] {
            assert_eq!(resolve(v, v), v);
        }
    }

    #[test]
    fn detection_is_stable_and_scalar_without_the_feature() {
        assert_eq!(detected(), detected());
        #[cfg(not(feature = "simd"))]
        assert_eq!(detected(), KernelVariant::Scalar);
    }

    fn randv(rng: &mut Rng, n: usize, lo: f64, hi: f64) -> Vec<f32> {
        (0..n).map(|_| rng.uniform(lo, hi) as f32).collect()
    }

    /// Random activations with zeros sprinkled in (panel-skip coverage).
    fn sparse_randv(rng: &mut Rng, n: usize, every: usize) -> Vec<f32> {
        (0..n).map(|i| if i % every == 0 { 0.0 } else { rng.uniform(-2.0, 2.0) as f32 }).collect()
    }

    #[test]
    fn accumulate_tiled_variants_match_scalar_within_envelope() {
        // every GEMM width in the model plus odd/remainder-heavy shapes
        for &(n, m) in &[
            (INV_DIM, EMB_INV),
            (DEP_DIM, EMB_DEP),
            (NODE_DIM, NODE_DIM),
            (7, 13),
            (9, 5),
            (4, 1),
        ] {
            let mut rng = Rng::new((n * 4099 + m) as u64);
            let x = sparse_randv(&mut rng, n, 3);
            let w = randv(&mut rng, n * m, -1.0, 1.0);
            let mut scalar = vec![0.25f64; m];
            kernels::accumulate_tiled(&x, &w, m, &mut scalar);
            for v in variants_up_to_detected() {
                let mut acc = vec![0.25f64; m];
                accumulate_tiled_v(v, &x, &w, m, &mut acc);
                for j in 0..m {
                    let what = format!("{}: n={n} m={m} j={j}", v.as_str());
                    assert_close(acc[j], scalar[j], &what);
                }
            }
        }
    }

    #[test]
    fn embed_and_gemm_variants_match_scalar_within_envelope() {
        let mut rng = Rng::new(77);
        let inv = randv(&mut rng, INV_DIM, -1.0, 1.0);
        let dep = randv(&mut rng, DEP_DIM, -1.0, 1.0);
        let w_inv = randv(&mut rng, INV_DIM * EMB_INV, -1.0, 1.0);
        let w_dep = randv(&mut rng, DEP_DIM * EMB_DEP, -1.0, 1.0);
        let b_inv = randv(&mut rng, EMB_INV, -0.5, 0.5);
        let b_dep = randv(&mut rng, EMB_DEP, -0.5, 0.5);
        let mut scalar_e = vec![0f32; NODE_DIM];
        kernels::embed_row(&inv, &dep, &w_inv, &b_inv, &w_dep, &b_dep, &mut scalar_e);
        let w = randv(&mut rng, NODE_DIM * NODE_DIM, -0.3, 0.3);
        let mut scalar_t = vec![0f32; NODE_DIM];
        kernels::gemm_row(&scalar_e, &w, &mut scalar_t);
        for v in variants_up_to_detected() {
            let mut e = vec![0f32; NODE_DIM];
            embed_row_v(v, &inv, &dep, &w_inv, &b_inv, &w_dep, &b_dep, &mut e);
            let mut t = vec![0f32; NODE_DIM];
            gemm_row_v(v, &scalar_e, &w, &mut t);
            for j in 0..NODE_DIM {
                let what = format!("embed {} j={j}", v.as_str());
                assert_close(e[j] as f64, scalar_e[j] as f64, &what);
                let what = format!("gemm {} j={j}", v.as_str());
                assert_close(t[j] as f64, scalar_t[j] as f64, &what);
            }
        }
    }

    #[test]
    fn qlinear_row_matches_naive_reference_and_variants_agree() {
        // odd n_out exercises the AVX2 remainder; n_out=1 is the head
        for &(n_in, n_out) in &[(80usize, 80usize), (48, 32), (17, 11), (240, 1)] {
            let mut rng = Rng::new((n_in * 31 + n_out) as u64);
            let x = sparse_randv(&mut rng, n_in, 4);
            let q: Vec<i8> = (0..n_in * n_out).map(|_| rng.uniform(-127.0, 127.0) as i8).collect();
            let scale = randv(&mut rng, n_out, 0.001, 0.02);
            let bias = randv(&mut rng, n_out, -0.5, 0.5);

            let mut out = vec![0f32; n_out];
            qlinear_row(&x, &q, &scale, Some(&bias), true, &mut out);
            // naive triple-loop reference
            for j in 0..n_out {
                let mut acc = 0f32;
                for i in 0..n_in {
                    acc += x[i] * q[i * n_out + j] as f32;
                }
                let expect = (acc * scale[j] + bias[j]).max(0.0);
                assert_eq!(out[j], expect, "scalar qlinear n_in={n_in} n_out={n_out} j={j}");
            }

            for v in variants_up_to_detected() {
                let mut vout = vec![0f32; n_out];
                qlinear_row_v(v, &x, &q, &scale, Some(&bias), true, &mut vout);
                for j in 0..n_out {
                    let tol = 1e-4f32 * out[j].abs().max(1.0);
                    assert!(
                        (vout[j] - out[j]).abs() <= tol,
                        "qlinear {} n_out={n_out} j={j}: {} vs {}",
                        v.as_str(),
                        vout[j],
                        out[j]
                    );
                }
            }
        }
    }
}
