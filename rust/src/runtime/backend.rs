//! The [`Backend`] trait: everything the rest of the system needs from a
//! GCN execution engine — inference, the Adagrad train step, and batched
//! runtime prediction. Every engine consumes the sparse variable-size
//! [`PackedBatch`]; the dense padded layout exists only inside the PJRT
//! engine (which converts right before upload) and the dense reference.
//!
//! Implementations:
//!
//! * [`crate::runtime::NativeBackend`] — the default pure-Rust sparse
//!   engine; no artifacts, no external runtime, always available;
//! * [`crate::runtime::DenseRefBackend`] — the padded dense reference,
//!   for parity tests and dense-vs-sparse benchmarks;
//! * `crate::runtime::GcnRuntime` (behind the `pjrt` cargo feature) — the
//!   PJRT path that executes the AOT HLO artifacts built by
//!   `python/compile/aot.py`.
//!
//! `train/`, `eval/`, `search/` and the examples are written against
//! `&dyn Backend`, so switching engines is a loader decision, not a code
//! change.

use crate::constants::BATCH;
use crate::dataset::sample::GraphSample;
use crate::features::normalize::FeatureStats;
use crate::model::PackedBatch;
use crate::runtime::kernels_simd::KernelVariant;
use crate::runtime::manifest::Manifest;
use crate::runtime::native::NativeBackend;
use crate::runtime::params::Params;
use anyhow::Result;
use std::path::Path;

/// A GCN execution engine. Object-safe: the training/eval/search layers
/// hold `&dyn Backend` / `Box<dyn Backend>`.
///
/// Engines are `Send + Sync`: the predict service shares one engine (via
/// its owning [`crate::predictor::Predictor`]) across worker threads and
/// concurrent callers, so all inference state must be immutable or
/// internally synchronized. The in-tree engines are plain data; a real
/// external PJRT binding substituted for the `xla` stub must be
/// thread-safe too.
pub trait Backend: Send + Sync {
    /// Model dimensions and the flat parameter calling convention.
    fn manifest(&self) -> &Manifest;

    /// Short identifier for logs ("native", "dense-ref", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// The microkernel tier this engine runs inference with. Everything
    /// defaults to the scalar bitwise-deterministic reference; only the
    /// native engine's explicit SIMD constructors report otherwise.
    fn kernel_variant(&self) -> KernelVariant {
        KernelVariant::Scalar
    }

    /// Predicted log-runtimes, one per graph of the batch.
    fn infer(&self, params: &Params, batch: &PackedBatch) -> Result<Vec<f32>>;

    /// One Adagrad step with an explicit learning rate; updates `params`
    /// and `accum` in place and returns the batch loss.
    fn train_step_lr(
        &self,
        params: &mut Params,
        accum: &mut Params,
        batch: &PackedBatch,
        lr: f32,
    ) -> Result<f32>;

    /// One Adagrad step at the manifest's learning rate.
    fn train_step(
        &self,
        params: &mut Params,
        accum: &mut Params,
        batch: &PackedBatch,
    ) -> Result<f32> {
        let lr = self.manifest().learning_rate as f32;
        self.train_step_lr(params, accum, batch, lr)
    }

    /// Fresh parameters for this backend's manifest (He/zeros/ones init).
    fn init_params(&self, seed: u64) -> Params {
        Params::init(self.manifest(), seed)
    }

    /// Predict mean runtimes in seconds for any number of samples of any
    /// size; samples are packed into batches internally. Backends may
    /// override this to parallelize over batch chunks (the native backend
    /// does, balancing chunks by total packed nodes so one big graph
    /// cannot straggle); each chunk must go through [`predict_chunk`] so
    /// the inference convention stays shared.
    fn predict_runtimes(
        &self,
        params: &Params,
        samples: &[&GraphSample],
        stats: &FeatureStats,
    ) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(samples.len());
        for chunk in samples.chunks(BATCH) {
            out.extend(predict_chunk(self, params, chunk, stats)?);
        }
        Ok(out)
    }
}

/// Run one chunk (≤ `BATCH` samples — a chunking policy, not a layout
/// cap) through `infer`: α/β loss weights are irrelevant for inference
/// (ones) and predictions come back as mean runtimes in seconds (`exp` of
/// the predicted log-runtime). Shared by the sequential
/// [`Backend::predict_runtimes`] default and the native backend's
/// parallel override so the two cannot drift.
pub fn predict_chunk<B: Backend + ?Sized>(
    backend: &B,
    params: &Params,
    chunk: &[&GraphSample],
    stats: &FeatureStats,
) -> Result<Vec<f64>> {
    let batch = PackedBatch::for_inference(chunk, stats)?;
    let z = backend.infer(params, &batch)?;
    Ok(z.iter().map(|&v| (v as f64).exp()).collect())
}

/// A non-fatal problem encountered while loading a backend (e.g. PJRT
/// artifacts present but unusable). The loaders *return* these instead of
/// printing to stderr, so library embedders stay quiet and the CLI decides
/// what to surface.
#[derive(Debug, Clone)]
pub struct BackendWarning {
    /// The engine the warning is about ("pjrt", ...).
    pub backend: &'static str,
    pub message: String,
}

impl std::fmt::Display for BackendWarning {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{} backend: {}", self.backend, self.message)
    }
}

/// A loaded backend plus any warnings produced on the way (empty in the
/// default build — only engine fallbacks warn).
pub struct LoadedBackend {
    pub backend: Box<dyn Backend>,
    pub warnings: Vec<BackendWarning>,
}

impl LoadedBackend {
    // only the pjrt success path constructs a warning-free value directly
    #[cfg_attr(not(feature = "pjrt"), allow(dead_code))]
    fn clean(backend: Box<dyn Backend>) -> LoadedBackend {
        LoadedBackend { backend, warnings: Vec::new() }
    }

    /// Discard warnings (callers that have no user-facing channel).
    pub fn ignore_warnings(self) -> Box<dyn Backend> {
        self.backend
    }

    /// Print warnings to stderr and return the backend — the standard
    /// CLI/example convenience. Library embedders that want different
    /// handling read `warnings` directly.
    pub fn warn_to_stderr(self) -> Box<dyn Backend> {
        for w in &self.warnings {
            eprintln!("warning: {w}");
        }
        self.backend
    }
}

/// Load the preferred backend for `artifacts_dir`.
///
/// With the `pjrt` feature enabled and artifacts present, the PJRT engine
/// is tried first and the native engine is the fallback (with a
/// [`BackendWarning`] explaining why); the default build always returns
/// the native engine (and needs no artifacts at all).
pub fn load_backend(artifacts_dir: &Path, with_train: bool) -> Result<LoadedBackend> {
    #[allow(unused_mut)]
    let mut warnings: Vec<BackendWarning> = Vec::new();
    #[cfg(feature = "pjrt")]
    {
        if artifacts_dir.join("manifest.json").exists() {
            match crate::runtime::gcn::GcnRuntime::load(artifacts_dir, with_train) {
                Ok(rt) => return Ok(LoadedBackend::clean(Box::new(rt))),
                Err(e) => warnings.push(BackendWarning {
                    backend: "pjrt",
                    message: format!("unavailable ({e:#}); falling back to native"),
                }),
            }
        }
    }
    let _ = (artifacts_dir, with_train);
    Ok(LoadedBackend { backend: Box::new(NativeBackend::new()), warnings })
}

/// Load a conv-depth ablation variant (`layers` graph-convolution layers).
///
/// Mirrors [`load_backend`]: PJRT variant artifacts when available under
/// the `pjrt` feature, the native engine otherwise.
pub fn load_variant_backend(
    artifacts_dir: &Path,
    layers: usize,
    with_train: bool,
) -> Result<LoadedBackend> {
    #[allow(unused_mut)]
    let mut warnings: Vec<BackendWarning> = Vec::new();
    #[cfg(feature = "pjrt")]
    {
        if artifacts_dir.join("manifest.json").exists() {
            let suffix = if layers == crate::constants::N_CONV {
                String::new()
            } else {
                format!("_l{layers}")
            };
            match crate::runtime::gcn::GcnRuntime::load_variant(artifacts_dir, &suffix, with_train)
            {
                Ok(mut rt) => {
                    // variants carry their own parameter lists
                    rt.manifest.n_conv = layers;
                    rt.manifest.params = crate::runtime::manifest::param_specs(layers);
                    return Ok(LoadedBackend::clean(Box::new(rt)));
                }
                Err(e) => warnings.push(BackendWarning {
                    backend: "pjrt",
                    message: format!("variant unavailable ({e:#}); falling back to native"),
                }),
            }
        }
    }
    let _ = (artifacts_dir, with_train);
    Ok(LoadedBackend { backend: Box::new(NativeBackend::with_layers(layers)), warnings })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_backend_is_native_without_artifacts() {
        let dir = std::env::temp_dir().join("gcn_perf_no_artifacts_here");
        let loaded = load_backend(&dir, true).unwrap();
        assert!(loaded.warnings.is_empty(), "no artifacts, nothing to warn about");
        let be = loaded.backend;
        assert_eq!(be.name(), "native");
        assert_eq!(be.manifest().n_conv, crate::constants::N_CONV);
    }

    #[test]
    fn variant_backend_layer_counts() {
        let dir = std::env::temp_dir().join("gcn_perf_no_artifacts_here");
        for layers in [0usize, 1, 2, 4] {
            let be = load_variant_backend(&dir, layers, false).unwrap().ignore_warnings();
            assert_eq!(be.manifest().n_conv, layers);
            assert_eq!(be.manifest().params.len(), 6 + 4 * layers);
        }
    }
}
