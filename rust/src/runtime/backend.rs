//! The [`Backend`] trait: everything the rest of the system needs from a
//! GCN execution engine — inference, the Adagrad train step, and batched
//! runtime prediction.
//!
//! Two implementations exist:
//!
//! * [`crate::runtime::NativeBackend`] — the default pure-Rust engine; no
//!   artifacts, no external runtime, always available;
//! * `crate::runtime::GcnRuntime` (behind the `pjrt` cargo feature) — the
//!   PJRT path that executes the AOT HLO artifacts built by
//!   `python/compile/aot.py`.
//!
//! `train/`, `eval/`, `search/` and the examples are written against
//! `&dyn Backend`, so switching engines is a loader decision, not a code
//! change.

use crate::constants::BATCH;
use crate::dataset::sample::GraphSample;
use crate::features::normalize::FeatureStats;
use crate::model::Batch;
use crate::runtime::manifest::Manifest;
use crate::runtime::native::NativeBackend;
use crate::runtime::params::Params;
use anyhow::Result;
use std::path::Path;

/// A GCN execution engine. Object-safe: the training/eval/search layers
/// hold `&dyn Backend` / `Box<dyn Backend>`.
pub trait Backend {
    /// Model dimensions and the flat parameter calling convention.
    fn manifest(&self) -> &Manifest;

    /// Short identifier for logs ("native", "pjrt", ...).
    fn name(&self) -> &'static str;

    /// Predicted log-runtimes for the real samples of the batch
    /// (`batch.len` values).
    fn infer(&self, params: &Params, batch: &Batch) -> Result<Vec<f32>>;

    /// One Adagrad step with an explicit learning rate; updates `params`
    /// and `accum` in place and returns the batch loss.
    fn train_step_lr(
        &self,
        params: &mut Params,
        accum: &mut Params,
        batch: &Batch,
        lr: f32,
    ) -> Result<f32>;

    /// One Adagrad step at the manifest's learning rate.
    fn train_step(
        &self,
        params: &mut Params,
        accum: &mut Params,
        batch: &Batch,
    ) -> Result<f32> {
        let lr = self.manifest().learning_rate as f32;
        self.train_step_lr(params, accum, batch, lr)
    }

    /// Fresh parameters for this backend's manifest (He/zeros/ones init).
    fn init_params(&self, seed: u64) -> Params {
        Params::init(self.manifest(), seed)
    }

    /// Predict mean runtimes in seconds for any number of samples; batches
    /// are padded internally. Backends may override this to parallelize
    /// over batch chunks (the native backend does); each chunk must go
    /// through [`predict_chunk`] so the inference convention stays shared.
    fn predict_runtimes(
        &self,
        params: &Params,
        samples: &[&GraphSample],
        stats: &FeatureStats,
    ) -> Result<Vec<f64>> {
        let mut out = Vec::with_capacity(samples.len());
        for chunk in samples.chunks(BATCH) {
            out.extend(predict_chunk(self, params, chunk, stats)?);
        }
        Ok(out)
    }
}

/// Run one padded chunk (≤ `BATCH` samples) through `infer`: α/β loss
/// weights are irrelevant for inference (fed as ones) and predictions come
/// back as mean runtimes in seconds (`exp` of the predicted log-runtime).
/// Shared by the sequential [`Backend::predict_runtimes`] default and the
/// native backend's parallel override so the two cannot drift.
pub fn predict_chunk<B: Backend + ?Sized>(
    backend: &B,
    params: &Params,
    chunk: &[&GraphSample],
    stats: &FeatureStats,
) -> Result<Vec<f64>> {
    let best = vec![1.0f64; chunk.len()];
    let batch = Batch::build(chunk, stats, &best);
    let z = backend.infer(params, &batch)?;
    Ok(z.iter().map(|&v| (v as f64).exp()).collect())
}

/// Load the preferred backend for `artifacts_dir`.
///
/// With the `pjrt` feature enabled and artifacts present, the PJRT engine
/// is tried first and the native engine is the fallback; the default build
/// always returns the native engine (and needs no artifacts at all).
pub fn load_backend(artifacts_dir: &Path, with_train: bool) -> Result<Box<dyn Backend>> {
    #[cfg(feature = "pjrt")]
    {
        if artifacts_dir.join("manifest.json").exists() {
            match crate::runtime::gcn::GcnRuntime::load(artifacts_dir, with_train) {
                Ok(rt) => return Ok(Box::new(rt)),
                Err(e) => {
                    eprintln!("pjrt backend unavailable ({e:#}); falling back to native")
                }
            }
        }
    }
    let _ = (artifacts_dir, with_train);
    Ok(Box::new(NativeBackend::new()))
}

/// Load a conv-depth ablation variant (`layers` graph-convolution layers).
///
/// Mirrors [`load_backend`]: PJRT variant artifacts when available under
/// the `pjrt` feature, the native engine otherwise.
pub fn load_variant_backend(
    artifacts_dir: &Path,
    layers: usize,
    with_train: bool,
) -> Result<Box<dyn Backend>> {
    #[cfg(feature = "pjrt")]
    {
        if artifacts_dir.join("manifest.json").exists() {
            let suffix = if layers == crate::constants::N_CONV {
                String::new()
            } else {
                format!("_l{layers}")
            };
            match crate::runtime::gcn::GcnRuntime::load_variant(artifacts_dir, &suffix, with_train)
            {
                Ok(mut rt) => {
                    // variants carry their own parameter lists
                    rt.manifest.n_conv = layers;
                    rt.manifest.params = crate::runtime::manifest::param_specs(layers);
                    return Ok(Box::new(rt));
                }
                Err(e) => {
                    eprintln!("pjrt variant unavailable ({e:#}); falling back to native")
                }
            }
        }
    }
    let _ = (artifacts_dir, with_train);
    Ok(Box::new(NativeBackend::with_layers(layers)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_backend_is_native_without_artifacts() {
        let dir = std::env::temp_dir().join("gcn_perf_no_artifacts_here");
        let be = load_backend(&dir, true).unwrap();
        assert_eq!(be.name(), "native");
        assert_eq!(be.manifest().n_conv, crate::constants::N_CONV);
    }

    #[test]
    fn variant_backend_layer_counts() {
        let dir = std::env::temp_dir().join("gcn_perf_no_artifacts_here");
        for layers in [0usize, 1, 2, 4] {
            let be = load_variant_backend(&dir, layers, false).unwrap();
            assert_eq!(be.manifest().n_conv, layers);
            assert_eq!(be.manifest().params.len(), 6 + 4 * layers);
        }
    }
}
