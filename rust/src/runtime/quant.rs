//! Int8 per-channel weight quantization for the reduced-precision
//! inference mode (`gcn-perf quantize`, `--precision int8`).
//!
//! Format: every dense GEMM weight matrix `W: [n_in, n_out]` is stored
//! as row-major `q: [n_in, n_out] i8` plus a per-output-channel
//! `scale: [n_out] f32`, with `scale_j = max_i |W[i,j]| / 127` and
//! `q[i,j] = round(W[i,j] / scale_j)` — symmetric quantization, one
//! rounding step of error per element. Inference accumulates
//! `Σ_i x_i · q[i,j]` in f32 and applies the scale (then bias/ReLU) once
//! per output channel — see `kernels_simd::qlinear_row`. Only the GEMM
//! weights (`w_inv`, `w_dep`, `conv{k}_w`, `w_out`) are quantized:
//! biases, channel-norm scale/shift and the O(E) CSR gather stay
//! f32/f64, so the normalization chain is shared with the f32 engine.
//!
//! The declared numeric envelope, asserted by the native-engine tests
//! and re-checked end-to-end by `eval::simd_bench`: per predicted
//! log-runtime `|z_int8 − z_f32| ≤` [`INT8_Z_ABS_TOL`]` + `
//! [`INT8_Z_REL_TOL`]`·|z_f32|`, and pairwise schedule-ranking agreement
//! with the f32 engine of at least [`INT8_RANK_AGREEMENT_MIN`] on the
//! zoo workloads. Int8 is opt-in serving precision only — training,
//! autotune checkpoints and loadgen verification stay on the
//! bitwise-deterministic f32 scalar path.

use crate::runtime::manifest::param_specs;
use crate::runtime::params::Params;
use anyhow::{ensure, Result};

/// Absolute term of the int8 log-runtime envelope.
pub const INT8_Z_ABS_TOL: f64 = 0.05;
/// Relative term of the int8 log-runtime envelope.
pub const INT8_Z_REL_TOL: f64 = 0.05;
/// Minimum pairwise ranking agreement of int8 vs f32 predictions.
pub const INT8_RANK_AGREEMENT_MIN: f64 = 0.9;

/// Whether a manifest parameter name is a dense GEMM weight (and is
/// therefore quantized): `w_inv`/`w_dep`/`w_out` and `conv{k}_w`.
pub(crate) fn is_gemm_weight(name: &str) -> bool {
    name.starts_with("w_") || name.ends_with("_w")
}

/// One quantized matrix: row-major i8 weights plus the per-output-channel
/// dequantization scales.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantMatrix {
    pub n_in: usize,
    pub n_out: usize,
    /// Row-major `[n_in, n_out]` quantized weights.
    pub q: Vec<i8>,
    /// Per-output-channel dequantization scale, `[n_out]`.
    pub scale: Vec<f32>,
}

impl QuantMatrix {
    /// Quantize a row-major `[n_in, n_out]` f32 matrix (symmetric,
    /// per-output-channel). All-zero channels keep scale 1.0 so their
    /// reconstruction is exact.
    pub fn quantize(w: &[f32], n_in: usize, n_out: usize) -> Result<QuantMatrix> {
        ensure!(
            w.len() == n_in * n_out,
            "matrix has {} elements, expected {n_in}x{n_out}",
            w.len()
        );
        let mut scale = vec![0f32; n_out];
        for (j, s) in scale.iter_mut().enumerate() {
            let mut mx = 0f32;
            for i in 0..n_in {
                mx = mx.max(w[i * n_out + j].abs());
            }
            *s = if mx > 0.0 { mx / 127.0 } else { 1.0 };
        }
        let mut q = vec![0i8; w.len()];
        for i in 0..n_in {
            for j in 0..n_out {
                q[i * n_out + j] = (w[i * n_out + j] / scale[j]).round() as i8;
            }
        }
        Ok(QuantMatrix { n_in, n_out, q, scale })
    }

    /// The f32 matrix this quantization represents (`q[i,j] · scale_j`).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0f32; self.q.len()];
        for i in 0..self.n_in {
            for j in 0..self.n_out {
                out[i * self.n_out + j] = self.q[i * self.n_out + j] as f32 * self.scale[j];
            }
        }
        out
    }
}

/// One quantized conv layer: int8 update weights, f32 everything else.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantConv {
    pub w: QuantMatrix,
    pub b: Vec<f32>,
    pub scale: Vec<f32>,
    pub shift: Vec<f32>,
}

/// A full quantized model in the manifest's flat layout: GEMM weights as
/// [`QuantMatrix`], every other tensor verbatim f32.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantParams {
    pub n_conv: usize,
    pub w_inv: QuantMatrix,
    pub b_inv: Vec<f32>,
    pub w_dep: QuantMatrix,
    pub b_dep: Vec<f32>,
    pub convs: Vec<QuantConv>,
    pub w_out: QuantMatrix,
    pub b_out: Vec<f32>,
}

impl QuantParams {
    /// Quantize a trained f32 parameter set (manifest layout, validated).
    pub fn from_params(params: &Params, n_conv: usize) -> Result<QuantParams> {
        let specs = param_specs(n_conv);
        ensure!(
            params.values.len() == specs.len(),
            "params have {} tensors, a {n_conv}-conv model has {}",
            params.values.len(),
            specs.len()
        );
        for (v, spec) in params.values.iter().zip(&specs) {
            ensure!(
                v.len() == spec.numel(),
                "param '{}' has {} elements, expected {}",
                spec.name,
                v.len(),
                spec.numel()
            );
        }
        let qm = |idx: usize| -> Result<QuantMatrix> {
            let shape = &specs[idx].shape;
            QuantMatrix::quantize(&params.values[idx], shape[0], shape[1])
        };
        let mut convs = Vec::with_capacity(n_conv);
        for k in 0..n_conv {
            convs.push(QuantConv {
                w: qm(4 + 4 * k)?,
                b: params.values[5 + 4 * k].clone(),
                scale: params.values[6 + 4 * k].clone(),
                shift: params.values[7 + 4 * k].clone(),
            });
        }
        let iw = 4 + 4 * n_conv;
        Ok(QuantParams {
            n_conv,
            w_inv: qm(0)?,
            b_inv: params.values[1].clone(),
            w_dep: qm(2)?,
            b_dep: params.values[3].clone(),
            convs,
            w_out: qm(iw)?,
            b_out: params.values[iw + 1].clone(),
        })
    }

    /// Rebuild an f32 [`Params`] in the manifest layout — weights via
    /// [`QuantMatrix::dequantize`], all other tensors verbatim. This is
    /// the model int8 inference effectively computes with.
    pub fn dequantize(&self) -> Params {
        let specs = param_specs(self.n_conv);
        let mut values = Vec::with_capacity(specs.len());
        values.push(self.w_inv.dequantize());
        values.push(self.b_inv.clone());
        values.push(self.w_dep.dequantize());
        values.push(self.b_dep.clone());
        for qc in &self.convs {
            values.push(qc.w.dequantize());
            values.push(qc.b.clone());
            values.push(qc.scale.clone());
            values.push(qc.shift.clone());
        }
        values.push(self.w_out.dequantize());
        values.push(self.b_out.clone());
        Params {
            values,
            shapes: specs.iter().map(|s| s.shape.clone()).collect(),
            names: specs.iter().map(|s| s.name.clone()).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::Manifest;
    use crate::util::rng::Rng;

    #[test]
    fn gemm_weight_predicate_matches_manifest_names() {
        for name in ["w_inv", "w_dep", "w_out", "conv0_w", "conv3_w"] {
            assert!(is_gemm_weight(name), "{name} is a GEMM weight");
        }
        for name in ["b_inv", "b_out", "conv0_b", "conv0_scale", "conv0_shift"] {
            assert!(!is_gemm_weight(name), "{name} is not a GEMM weight");
        }
    }

    #[test]
    fn quantize_bounds_per_element_error_by_half_a_step() {
        let (n_in, n_out) = (17usize, 9usize);
        let mut rng = Rng::new(7);
        let mut w: Vec<f32> =
            (0..n_in * n_out).map(|_| rng.uniform(-3.0, 3.0) as f32).collect();
        // one all-zero output channel
        for i in 0..n_in {
            w[i * n_out + 4] = 0.0;
        }
        let qm = QuantMatrix::quantize(&w, n_in, n_out).unwrap();
        assert_eq!(qm.scale.len(), n_out);
        let back = qm.dequantize();
        for i in 0..n_in {
            for j in 0..n_out {
                let err = (w[i * n_out + j] - back[i * n_out + j]).abs();
                assert!(
                    err as f64 <= qm.scale[j] as f64 * 0.5 + 1e-7,
                    "element ({i},{j}) err {err} exceeds half a step {}",
                    qm.scale[j]
                );
            }
        }
        for i in 0..n_in {
            assert_eq!(back[i * n_out + 4], 0.0, "zero channel must reconstruct exactly");
        }
        assert!(QuantMatrix::quantize(&w, n_in, n_out + 1).is_err());
    }

    #[test]
    fn from_params_roundtrips_layout_and_non_weight_tensors() {
        let m = Manifest::native(2);
        let params = Params::init(&m, 11);
        let qp = QuantParams::from_params(&params, 2).unwrap();
        assert_eq!(qp.convs.len(), 2);
        let back = qp.dequantize();
        assert_eq!(back.names, params.names);
        assert_eq!(back.shapes, params.shapes);
        for (t, name) in params.names.iter().enumerate() {
            if is_gemm_weight(name) {
                continue; // weights reconstruct approximately, not bitwise
            }
            assert_eq!(back.values[t], params.values[t], "non-weight '{name}' must be verbatim");
        }
    }

    #[test]
    fn from_params_rejects_layer_mismatch() {
        let params = Params::init(&Manifest::native(2), 3);
        assert!(QuantParams::from_params(&params, 1).is_err());
    }
}
