//! GCN execution backends behind the [`Backend`] trait. Every backend
//! consumes the sparse variable-size [`crate::model::PackedBatch`].
//!
//! * [`native`] — the default pure-Rust engine (no artifacts, no external
//!   runtime): blocked GEMMs over the packed node matrix plus O(E)
//!   CSR gather-scatter aggregation; no `MAX_NODES`/`BATCH` caps.
//! * [`dense_ref`] — the padded dense reference engine the sparse path
//!   replaced; kept for parity tests and dense-vs-sparse benchmarks.
//! * `gcn` (behind the `pjrt` cargo feature) — loads the AOT HLO-text
//!   artifacts produced by `python/compile/aot.py`, converts packed
//!   batches to the fixed dense shapes the artifacts were compiled for,
//!   and drives inference/training through XLA.
//!
//! Use [`load_backend`] / [`load_variant_backend`] to get the right engine
//! for the current build; python is never on either path at runtime.

pub mod backend;
pub mod dense_ref;
pub(crate) mod kernels;
pub mod manifest;
pub mod native;
pub mod params;
pub mod workspace;

#[cfg(feature = "pjrt")]
pub mod gcn;

pub use backend::{
    load_backend, load_variant_backend, Backend, BackendWarning, LoadedBackend,
};
pub use dense_ref::DenseRefBackend;
#[cfg(feature = "pjrt")]
pub use gcn::GcnRuntime;
pub use manifest::Manifest;
pub use native::NativeBackend;
pub use params::Params;
pub use workspace::{Workspace, WorkspaceStats};
