//! GCN execution backends behind the [`Backend`] trait.
//!
//! * [`native`] — the default pure-Rust engine (no artifacts, no external
//!   runtime); implements the forward pass and the Adagrad train step with
//!   the exact artifact semantics of `python/compile/aot.py`.
//! * `gcn` (behind the `pjrt` cargo feature) — loads the AOT HLO-text
//!   artifacts produced by `python/compile/aot.py`, compiles them on the
//!   PJRT CPU client and drives inference/training through XLA.
//!
//! Use [`load_backend`] / [`load_variant_backend`] to get the right engine
//! for the current build; python is never on either path at runtime.

pub mod backend;
pub mod manifest;
pub mod native;
pub mod params;

#[cfg(feature = "pjrt")]
pub mod gcn;

pub use backend::{
    load_backend, load_variant_backend, Backend, BackendWarning, LoadedBackend,
};
#[cfg(feature = "pjrt")]
pub use gcn::GcnRuntime;
pub use manifest::Manifest;
pub use native::NativeBackend;
pub use params::Params;
