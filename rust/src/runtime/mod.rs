//! PJRT runtime: load the AOT HLO-text artifacts produced by
//! `python/compile/aot.py`, compile them on the CPU PJRT client, and drive
//! inference / training from rust. Python is never on this path.

pub mod manifest;
pub mod params;
pub mod gcn;

pub use gcn::GcnRuntime;
pub use manifest::Manifest;
pub use params::Params;
