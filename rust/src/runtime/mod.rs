//! GCN execution backends behind the [`Backend`] trait. Every backend
//! consumes the sparse variable-size [`crate::model::PackedBatch`].
//!
//! * [`native`] — the default pure-Rust engine (no artifacts, no external
//!   runtime): blocked GEMMs over the packed node matrix plus O(E)
//!   CSR gather-scatter aggregation; no `MAX_NODES`/`BATCH` caps.
//! * [`dense_ref`] — the padded dense reference engine the sparse path
//!   replaced; kept for parity tests and dense-vs-sparse benchmarks.
//! * `gcn` (behind the `pjrt` cargo feature) — loads the AOT HLO-text
//!   artifacts produced by `python/compile/aot.py`, converts packed
//!   batches to the fixed dense shapes the artifacts were compiled for,
//!   and drives inference/training through XLA.
//!
//! The native engine's inner loops live in `kernels` (the scalar,
//! bitwise-deterministic reference) and [`kernels_simd`] (opt-in
//! `std::arch` variants behind the `simd` cargo feature, selected by
//! one-time runtime CPU detection); [`quant`] holds the int8 per-channel
//! weight containers for the reduced-precision inference mode.
//!
//! Use [`load_backend`] / [`load_variant_backend`] to get the right engine
//! for the current build; python is never on either path at runtime.

pub mod backend;
pub mod dense_ref;
pub(crate) mod kernels;
pub mod kernels_simd;
pub mod manifest;
pub mod native;
pub mod params;
pub mod quant;
pub mod workspace;

#[cfg(feature = "pjrt")]
pub mod gcn;

pub use backend::{
    load_backend, load_variant_backend, Backend, BackendWarning, LoadedBackend,
};
pub use dense_ref::DenseRefBackend;
#[cfg(feature = "pjrt")]
pub use gcn::GcnRuntime;
pub use kernels_simd::KernelVariant;
pub use manifest::Manifest;
pub use native::NativeBackend;
pub use params::Params;
pub use quant::{QuantConv, QuantMatrix, QuantParams};
pub use workspace::{Workspace, WorkspaceStats};
