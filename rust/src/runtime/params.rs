//! Model parameters + Adagrad accumulators, host-side.
//!
//! Initialization matches `model.init_params` (He for matrices, zeros for
//! biases, ones for norm scales) — the exact values differ (different RNG)
//! but the distribution is the same; training happens in rust anyway.

use crate::runtime::manifest::Manifest;
use crate::util::rng::Rng;
use anyhow::{bail, Context, Result};
use std::io::{Read, Write};
use std::path::Path;

const MAGIC: &[u8; 8] = b"GCNPARAM";

/// Flat parameter set in manifest order.
#[derive(Debug, Clone)]
pub struct Params {
    pub values: Vec<Vec<f32>>,
    pub shapes: Vec<Vec<usize>>,
    pub names: Vec<String>,
}

impl Params {
    /// He/zeros/ones initialization per the parameter's role.
    pub fn init(manifest: &Manifest, seed: u64) -> Params {
        let mut rng = Rng::new(seed);
        let mut values = Vec::new();
        let mut shapes = Vec::new();
        let mut names = Vec::new();
        for spec in &manifest.params {
            let n = spec.numel();
            let v = if spec.name.ends_with("_scale") {
                vec![1.0f32; n]
            } else if spec.shape.len() == 1 {
                vec![0.0f32; n]
            } else {
                let fan_in = spec.shape[0] as f64;
                let std = (2.0 / fan_in).sqrt();
                (0..n).map(|_| (rng.normal() * std) as f32).collect()
            };
            values.push(v);
            shapes.push(spec.shape.clone());
            names.push(spec.name.clone());
        }
        Params { values, shapes, names }
    }

    /// All-zeros clone with the same shapes (Adagrad accumulator init).
    pub fn zeros_like(&self) -> Params {
        Params {
            values: self.values.iter().map(|v| vec![0.0; v.len()]).collect(),
            shapes: self.shapes.clone(),
            names: self.names.clone(),
        }
    }

    pub fn total_elems(&self) -> usize {
        self.values.iter().map(|v| v.len()).sum()
    }

    /// Save to a binary checkpoint.
    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(self.values.len() as u32).to_le_bytes())?;
        for (v, (shape, name)) in self.values.iter().zip(self.shapes.iter().zip(&self.names)) {
            let nb = name.as_bytes();
            f.write_all(&(nb.len() as u32).to_le_bytes())?;
            f.write_all(nb)?;
            f.write_all(&(shape.len() as u32).to_le_bytes())?;
            for &d in shape {
                f.write_all(&(d as u32).to_le_bytes())?;
            }
            for x in v {
                f.write_all(&x.to_le_bytes())?;
            }
        }
        Ok(())
    }

    /// Load a checkpoint and verify it matches the manifest layout.
    pub fn load(path: &Path, manifest: &Manifest) -> Result<Params> {
        let mut f = std::io::BufReader::new(
            std::fs::File::open(path).with_context(|| format!("open {path:?}"))?,
        );
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            bail!("not a param checkpoint");
        }
        let mut b4 = [0u8; 4];
        f.read_exact(&mut b4)?;
        let n = u32::from_le_bytes(b4) as usize;
        if n != manifest.params.len() {
            bail!("checkpoint has {n} tensors, manifest expects {}", manifest.params.len());
        }
        let mut values = Vec::new();
        let mut shapes = Vec::new();
        let mut names = Vec::new();
        for spec in &manifest.params {
            f.read_exact(&mut b4)?;
            let name_len = u32::from_le_bytes(b4) as usize;
            let mut nb = vec![0u8; name_len];
            f.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            if name != spec.name {
                bail!("checkpoint param '{name}' where manifest expects '{}'", spec.name);
            }
            f.read_exact(&mut b4)?;
            let rank = u32::from_le_bytes(b4) as usize;
            let mut shape = Vec::with_capacity(rank);
            for _ in 0..rank {
                f.read_exact(&mut b4)?;
                shape.push(u32::from_le_bytes(b4) as usize);
            }
            if shape != spec.shape {
                bail!("param '{name}' shape {shape:?} != manifest {:?}", spec.shape);
            }
            let numel: usize = shape.iter().product();
            let mut buf = vec![0u8; numel * 4];
            f.read_exact(&mut buf)?;
            values.push(
                buf.chunks_exact(4)
                    .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                    .collect(),
            );
            shapes.push(shape);
            names.push(name);
        }
        Ok(Params { values, shapes, names })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::manifest::{Manifest, ParamSpec};

    fn tiny_manifest() -> Manifest {
        Manifest {
            inv_dim: crate::constants::INV_DIM,
            dep_dim: crate::constants::DEP_DIM,
            node_dim: 80,
            n_conv: 0,
            max_nodes: crate::constants::MAX_NODES,
            batch: crate::constants::BATCH,
            learning_rate: 0.0075,
            weight_decay: 1e-4,
            params: vec![
                ParamSpec { name: "w".into(), shape: vec![4, 8] },
                ParamSpec { name: "b".into(), shape: vec![8] },
                ParamSpec { name: "n_scale".into(), shape: vec![8] },
            ],
            ablation_layers: vec![],
        }
    }

    #[test]
    fn init_roles() {
        let p = Params::init(&tiny_manifest(), 1);
        assert_eq!(p.values[0].len(), 32);
        assert!(p.values[0].iter().any(|&x| x != 0.0)); // weights random
        assert!(p.values[1].iter().all(|&x| x == 0.0)); // bias zero
        assert!(p.values[2].iter().all(|&x| x == 1.0)); // scale one
    }

    #[test]
    fn save_load_roundtrip() {
        let m = tiny_manifest();
        let p = Params::init(&m, 2);
        let path = std::env::temp_dir().join("gcn_perf_test_params.bin");
        p.save(&path).unwrap();
        let q = Params::load(&path, &m).unwrap();
        assert_eq!(p.values, q.values);
        assert_eq!(p.shapes, q.shapes);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_shape_mismatch() {
        let m = tiny_manifest();
        let p = Params::init(&m, 3);
        let path = std::env::temp_dir().join("gcn_perf_test_params2.bin");
        p.save(&path).unwrap();
        let mut m2 = m.clone();
        m2.params[0].shape = vec![5, 8];
        assert!(Params::load(&path, &m2).is_err());
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn zeros_like_matches_layout() {
        let p = Params::init(&tiny_manifest(), 4);
        let z = p.zeros_like();
        assert_eq!(z.total_elems(), p.total_elems());
        assert!(z.values.iter().flatten().all(|&x| x == 0.0));
    }
}
