//! Reusable buffer arena for the native engine's hot loops.
//!
//! Every `forward`/`infer`/`backward` of the pre-PR-5 engine allocated
//! its node matrices fresh (and the parallel row fill allocated *again*
//! per block, then copied into a joined `Vec`). Under the serving layer
//! that cost moved to the top of the profile: the coalescer worker runs
//! thousands of inference passes over recycled batch shapes, so the same
//! buffer sizes are requested over and over.
//!
//! [`Workspace`] is a size-class-free pool: [`Workspace::take_f32`] /
//! [`Workspace::take_f64`] return a zeroed buffer of the requested
//! length, reusing any pooled buffer whose capacity suffices;
//! [`Workspace::recycle_f32`] / [`Workspace::recycle_f64`] return
//! buffers to the pool. Once the pool has seen a workload's shapes, a
//! steady-state `infer`/`train_step` performs no node-matrix heap
//! allocation at all (pinned by the engine's allocation-budget test via
//! [`crate::util::alloc_count`]).
//!
//! Ownership model: the native engine owns a small **pool** of arenas
//! (`NativeBackend::with_ws`) and hands one to each call, so buffers
//! stay warm no matter which thread runs the kernels — long-lived
//! threads (the `PredictService` coalescer worker, a training loop) and
//! the short-lived scoped workers of a `predict_runtimes` fan-out alike
//! (a thread-local arena would start cold on every fresh scoped
//! thread). Callers that want explicit control (tests, the bench
//! harness) construct a [`Workspace`] directly and pass it to the `_ws`
//! engine entry points.

/// Upper bound on pooled buffers per element type. The engine needs ~a
/// dozen live buffers per train step; anything beyond this cap is
/// genuinely idle and returned to the allocator instead of hoarded.
const POOL_CAP: usize = 32;

/// Running reuse counters, for tests and the engine micro-bench report.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct WorkspaceStats {
    /// `take_*` calls served from the pool without allocating.
    pub hits: u64,
    /// `take_*` calls that had to allocate a new buffer.
    pub misses: u64,
}

/// A recycled-buffer arena. See the module docs for the lifecycle.
#[derive(Debug, Default)]
pub struct Workspace {
    f32_pool: Vec<Vec<f32>>,
    f64_pool: Vec<Vec<f64>>,
    stats: WorkspaceStats,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace::default()
    }

    /// A zeroed f32 buffer of exactly `len` elements, recycled when the
    /// pool holds one with enough capacity.
    pub fn take_f32(&mut self, len: usize) -> Vec<f32> {
        match self.f32_pool.iter().position(|b| b.capacity() >= len) {
            Some(pos) => {
                self.stats.hits += 1;
                let mut v = self.f32_pool.swap_remove(pos);
                v.clear();
                v.resize(len, 0.0);
                v
            }
            None => {
                self.stats.misses += 1;
                vec![0.0; len]
            }
        }
    }

    /// A zeroed f64 buffer of exactly `len` elements.
    pub fn take_f64(&mut self, len: usize) -> Vec<f64> {
        match self.f64_pool.iter().position(|b| b.capacity() >= len) {
            Some(pos) => {
                self.stats.hits += 1;
                let mut v = self.f64_pool.swap_remove(pos);
                v.clear();
                v.resize(len, 0.0);
                v
            }
            None => {
                self.stats.misses += 1;
                vec![0.0; len]
            }
        }
    }

    /// Return a buffer to the pool for reuse.
    pub fn recycle_f32(&mut self, v: Vec<f32>) {
        if self.f32_pool.len() < POOL_CAP && v.capacity() > 0 {
            self.f32_pool.push(v);
        }
    }

    /// Return a buffer to the pool for reuse.
    pub fn recycle_f64(&mut self, v: Vec<f64>) {
        if self.f64_pool.len() < POOL_CAP && v.capacity() > 0 {
            self.f64_pool.push(v);
        }
    }

    pub fn stats(&self) -> WorkspaceStats {
        self.stats
    }

    /// Drop every pooled buffer (the stats survive).
    pub fn clear(&mut self) {
        self.f32_pool.clear();
        self.f64_pool.clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn take_zeroes_and_recycling_hits() {
        let mut ws = Workspace::new();
        let mut a = ws.take_f32(100);
        assert_eq!(a.len(), 100);
        assert!(a.iter().all(|&v| v == 0.0));
        a.iter_mut().for_each(|v| *v = 7.0);
        ws.recycle_f32(a);
        // same size comes back zeroed, without allocating
        let b = ws.take_f32(100);
        assert!(b.iter().all(|&v| v == 0.0), "recycled buffer must be zeroed");
        assert_eq!(ws.stats(), WorkspaceStats { hits: 1, misses: 1 });
        ws.recycle_f32(b);
        // a smaller request reuses the same capacity
        let c = ws.take_f32(40);
        assert_eq!(c.len(), 40);
        assert_eq!(ws.stats().hits, 2);
        ws.recycle_f32(c);
        // a larger request cannot reuse it
        let d = ws.take_f32(4000);
        assert_eq!(d.len(), 4000);
        assert_eq!(ws.stats().misses, 2);
    }

    #[test]
    fn f64_pool_is_independent() {
        let mut ws = Workspace::new();
        let a = ws.take_f64(64);
        ws.recycle_f64(a);
        let _f32 = ws.take_f32(64);
        assert_eq!(ws.stats().misses, 2, "f32 request must not steal the f64 buffer");
        let b = ws.take_f64(64);
        assert_eq!(b.len(), 64);
        assert_eq!(ws.stats().hits, 1);
    }

    #[test]
    fn steady_state_take_recycle_does_not_allocate() {
        let mut ws = Workspace::new();
        for _ in 0..3 {
            let a = ws.take_f32(512);
            let b = ws.take_f64(256);
            ws.recycle_f32(a);
            ws.recycle_f64(b);
        }
        let before = crate::util::alloc_count::thread_alloc_count();
        for _ in 0..10 {
            let a = ws.take_f32(512);
            let b = ws.take_f64(256);
            ws.recycle_f32(a);
            ws.recycle_f64(b);
        }
        let delta = crate::util::alloc_count::thread_alloc_count() - before;
        assert_eq!(delta, 0, "warm take/recycle cycles must not touch the heap");
    }
}
