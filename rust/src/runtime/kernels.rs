//! Register-tiled microkernels shared by the native engine's training
//! forward and its inference fast path.
//!
//! Everything here preserves the engine's numeric contract: **f64
//! accumulation, f32 storage, and the same per-accumulator summation
//! chain as the pre-tiled scalar loops** (for each output `j`, terms are
//! added in ascending input order `i`). Tiling only restructures *which*
//! memory is touched when:
//!
//! * the dual embedding used to run output-outer / input-inner, reading
//!   the weight matrix at stride `EMB_*`; [`embed_row`] runs input-outer
//!   over contiguous weight rows instead (a rank-1-update microkernel),
//!   which is the same chain per output `j` — just vectorizable;
//! * [`accumulate_tiled`] unrolls the input dimension in panels of
//!   [`TILE_I`] rows via `chunks_exact`, keeping the four scalars in
//!   registers while streaming four contiguous weight rows, and skips
//!   all-zero panels (the post-ReLU activations the conv GEMM consumes
//!   are mostly zeros). Skipping a `+= 0·w` term can only change a
//!   `-0.0` into `+0.0`, which no consumer distinguishes;
//! * [`conv_row_infer`] fuses the CSR gather `A'·t` with bias, channel
//!   norm and ReLU in one pass over the row and materializes only the
//!   next activation — the backprop stash (`h`/`xhat`/`rstd`) that
//!   [`conv_row_train`] keeps is skipped entirely.
//!
//! Because the fast path and the training forward call these same
//! functions with the same chain, their outputs are bit-identical; the
//! JAX parity fixtures continue to pin both against the reference
//! numbers at ≤1e-5.

use crate::constants::{EMB_DEP, EMB_INV, NODE_DIM};
use crate::model::PackedBatch;
use crate::runtime::native::LN_EPS;

/// Input rows consumed per microkernel step. Four f64 accumuland streams
/// fit comfortably in registers next to the accumulator tile, and the
/// all-zero skip still fires often on post-ReLU activations.
const TILE_I: usize = 4;

/// `acc[j] += Σ_i x[i] · w[i·m + j]`, input-outer with [`TILE_I`]-row
/// panels. Per output `j` the terms are added in ascending `i` — the
/// same chain as a scalar sweep — and panels whose four inputs are all
/// zero are skipped.
pub(crate) fn accumulate_tiled(x: &[f32], w: &[f32], m: usize, acc: &mut [f64]) {
    debug_assert_eq!(acc.len(), m);
    debug_assert_eq!(w.len(), x.len() * m);
    let mut panels = x.chunks_exact(TILE_I);
    let mut i = 0usize;
    for p in panels.by_ref() {
        if p[0] == 0.0 && p[1] == 0.0 && p[2] == 0.0 && p[3] == 0.0 {
            i += TILE_I;
            continue;
        }
        let (x0, x1, x2, x3) = (p[0] as f64, p[1] as f64, p[2] as f64, p[3] as f64);
        let w0 = &w[i * m..(i + 1) * m];
        let w1 = &w[(i + 1) * m..(i + 2) * m];
        let w2 = &w[(i + 2) * m..(i + 3) * m];
        let w3 = &w[(i + 3) * m..(i + 4) * m];
        for j in 0..m {
            let mut a = acc[j];
            a += x0 * w0[j] as f64;
            a += x1 * w1[j] as f64;
            a += x2 * w2[j] as f64;
            a += x3 * w3[j] as f64;
            acc[j] = a;
        }
        i += TILE_I;
    }
    for &xv in panels.remainder() {
        if xv != 0.0 {
            let xf = xv as f64;
            let wrow = &w[i * m..(i + 1) * m];
            for j in 0..m {
                acc[j] += xf * wrow[j] as f64;
            }
        }
        i += 1;
    }
}

/// Fig 5 dual embedding for one node:
/// `out = relu(inv·Wi + bi) ++ relu(dep·Wd + bd)`.
pub(crate) fn embed_row(
    inv: &[f32],
    dep: &[f32],
    w_inv: &[f32],
    b_inv: &[f32],
    w_dep: &[f32],
    b_dep: &[f32],
    out: &mut [f32],
) {
    debug_assert_eq!(out.len(), NODE_DIM);
    let mut acc = [0f64; NODE_DIM];
    for (a, &b) in acc[..EMB_INV].iter_mut().zip(b_inv) {
        *a = b as f64;
    }
    accumulate_tiled(inv, w_inv, EMB_INV, &mut acc[..EMB_INV]);
    for (a, &b) in acc[EMB_INV..].iter_mut().zip(b_dep) {
        *a = b as f64;
    }
    accumulate_tiled(dep, w_dep, EMB_DEP, &mut acc[EMB_INV..]);
    for (o, &a) in out.iter_mut().zip(&acc) {
        *o = a.max(0.0) as f32;
    }
}

/// One row of the conv projection `t = E · W` (output width `NODE_DIM`).
pub(crate) fn gemm_row(e_row: &[f32], w: &[f32], out: &mut [f32]) {
    debug_assert_eq!(out.len(), NODE_DIM);
    let mut acc = [0f64; NODE_DIM];
    accumulate_tiled(e_row, w, NODE_DIM, &mut acc);
    for (o, &a) in out.iter_mut().zip(&acc) {
        *o = a as f32;
    }
}

/// `c = A'·t + b` for one node — the O(E) CSR gather — in f64. Shared
/// with the SIMD layer (`kernels_simd`), whose vectorized gathers must
/// feed the identical downstream norm chain.
#[inline]
pub(crate) fn gather_row(
    batch: &PackedBatch,
    t: &[f32],
    node: usize,
    bvec: &[f32],
) -> [f64; NODE_DIM] {
    let (cols, vals) = batch.adj.row(node);
    let mut c = [0f64; NODE_DIM];
    for (&cix, &a) in cols.iter().zip(vals) {
        let af = a as f64;
        let t_row = &t[cix as usize * NODE_DIM..(cix as usize + 1) * NODE_DIM];
        for j in 0..NODE_DIM {
            c[j] += af * t_row[j] as f64;
        }
    }
    for (cj, &b) in c.iter_mut().zip(bvec) {
        *cj += b as f64;
    }
    c
}

/// Channel-norm statistics `(mean, 1/√(var+ε))` over one gathered row.
/// Horizontal reductions are where SIMD lane order would change the
/// chain, so every kernel tier — scalar and vectorized — calls this one
/// scalar implementation.
#[inline]
pub(crate) fn norm_stats(c: &[f64; NODE_DIM]) -> (f64, f64) {
    let mean = c.iter().sum::<f64>() / NODE_DIM as f64;
    let var = c.iter().map(|&x| (x - mean) * (x - mean)).sum::<f64>() / NODE_DIM as f64;
    (mean, 1.0 / (var + LN_EPS).sqrt())
}

/// Inference conv row: gather + bias + channel norm + scale/shift + ReLU
/// fused, writing only the next activation (no backprop stash).
pub(crate) fn conv_row_infer(
    batch: &PackedBatch,
    t: &[f32],
    node: usize,
    bvec: &[f32],
    scale: &[f32],
    shift: &[f32],
    e_next: &mut [f32],
) {
    let c = gather_row(batch, t, node, bvec);
    let (mean, rs) = norm_stats(&c);
    for j in 0..NODE_DIM {
        let xh = (c[j] - mean) * rs;
        let hv = xh * scale[j] as f64 + shift[j] as f64;
        e_next[j] = hv.max(0.0) as f32;
    }
}

/// Training conv row: same arithmetic chain as [`conv_row_infer`], but
/// additionally stashes `h` (post-norm pre-ReLU), `xhat` (normalized)
/// and returns `rstd` for the backward pass.
pub(crate) fn conv_row_train(
    batch: &PackedBatch,
    t: &[f32],
    node: usize,
    bvec: &[f32],
    scale: &[f32],
    shift: &[f32],
    h: &mut [f32],
    xhat: &mut [f32],
    e_next: &mut [f32],
) -> f32 {
    let c = gather_row(batch, t, node, bvec);
    let (mean, rs) = norm_stats(&c);
    for j in 0..NODE_DIM {
        let xh = (c[j] - mean) * rs;
        xhat[j] = xh as f32;
        let hv = xh * scale[j] as f64 + shift[j] as f64;
        h[j] = hv as f32;
        e_next[j] = hv.max(0.0) as f32;
    }
    rs as f32
}

/// Accumulate one readout level into `feat`:
/// `feat[g, level·NODE_DIM + j] += Σ_{nodes of g} e[node, j]`, f32
/// accumulation in packed node order (the training forward's chain).
pub(crate) fn readout_level(
    batch: &PackedBatch,
    e: &[f32],
    level: usize,
    readout: usize,
    feat: &mut [f32],
) {
    for g in 0..batch.n_graphs() {
        let f_off = g * readout + level * NODE_DIM;
        let feat_row = &mut feat[f_off..f_off + NODE_DIM];
        for node in batch.graph_nodes(g) {
            let row = &e[node * NODE_DIM..(node + 1) * NODE_DIM];
            for (fj, &v) in feat_row.iter_mut().zip(row) {
                *fj += v;
            }
        }
    }
}

/// Linear head for one graph: `z = feat · w_out + b_out`.
pub(crate) fn head_row(feat_row: &[f32], w_out: &[f32], b_out0: f32) -> f32 {
    let mut acc = b_out0 as f64;
    for (&f, &w) in feat_row.iter().zip(w_out) {
        acc += f as f64 * w as f64;
    }
    acc as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::constants::{DEP_DIM, INV_DIM};
    use crate::dataset::builder::{build_dataset, DataGenConfig};
    use crate::dataset::sample::GraphSample;
    use crate::util::rng::Rng;

    #[test]
    fn tiled_accumulation_matches_scalar_chain_bitwise() {
        // widths of every GEMM in the model, plus a remainder-heavy case
        for &(n, m) in &[(INV_DIM, EMB_INV), (DEP_DIM, EMB_DEP), (NODE_DIM, NODE_DIM), (7, 13)] {
            let mut rng = Rng::new((n * 1000 + m) as u64);
            let x: Vec<f32> = (0..n)
                .map(|i| if i % 3 == 0 { 0.0 } else { rng.uniform(-2.0, 2.0) as f32 })
                .collect();
            let w: Vec<f32> = (0..n * m).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            let mut acc = vec![0.125f64; m];
            let mut reference = acc.clone();
            accumulate_tiled(&x, &w, m, &mut acc);
            // the pre-tiled chain: per output j, ascending i
            for (j, r) in reference.iter_mut().enumerate() {
                for i in 0..n {
                    *r += x[i] as f64 * w[i * m + j] as f64;
                }
            }
            assert_eq!(acc, reference, "tiling changed the summation chain (n={n}, m={m})");
        }
    }

    #[test]
    fn tiled_accumulation_skips_zero_panels() {
        // an all-zero input contributes nothing and must not disturb acc
        let x = vec![0f32; 16];
        let w = vec![3.5f32; 16 * 4];
        let mut acc = vec![1.5f64; 4];
        accumulate_tiled(&x, &w, 4, &mut acc);
        assert_eq!(acc, vec![1.5f64; 4]);
    }

    #[test]
    fn embed_row_matches_output_outer_reference() {
        let mut rng = Rng::new(99);
        let inv: Vec<f32> = (0..INV_DIM).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let dep: Vec<f32> = (0..DEP_DIM).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let w_inv: Vec<f32> =
            (0..INV_DIM * EMB_INV).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let w_dep: Vec<f32> =
            (0..DEP_DIM * EMB_DEP).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let b_inv: Vec<f32> = (0..EMB_INV).map(|_| rng.uniform(-0.5, 0.5) as f32).collect();
        let b_dep: Vec<f32> = (0..EMB_DEP).map(|_| rng.uniform(-0.5, 0.5) as f32).collect();
        let mut out = vec![0f32; NODE_DIM];
        embed_row(&inv, &dep, &w_inv, &b_inv, &w_dep, &b_dep, &mut out);
        // the pre-tiled engine's loop shape: output-outer, input-inner
        for j in 0..EMB_INV {
            let mut acc = b_inv[j] as f64;
            for (i, &x) in inv.iter().enumerate() {
                acc += x as f64 * w_inv[i * EMB_INV + j] as f64;
            }
            assert_eq!(out[j], acc.max(0.0) as f32, "inv half diverges at {j}");
        }
        for j in 0..EMB_DEP {
            let mut acc = b_dep[j] as f64;
            for (i, &x) in dep.iter().enumerate() {
                acc += x as f64 * w_dep[i * EMB_DEP + j] as f64;
            }
            assert_eq!(out[EMB_INV + j], acc.max(0.0) as f32, "dep half diverges at {j}");
        }
    }

    // ---- property pins: scalar kernels vs naive triple-loop references.
    // The documented contract (per output j, ascending input i, f64
    // accumulation, zero panels skipped) is what the SIMD layer is
    // validated against, so it gets pinned bitwise at the kernel level.

    #[test]
    fn accumulate_tiled_matches_naive_on_odd_shapes() {
        // pure-remainder (n < TILE_I), odd, and panel+remainder shapes
        for &(n, m) in &[(1usize, 1usize), (2, 3), (3, 7), (5, 4), (6, 9), (15, 17), (8, 2)] {
            let mut rng = Rng::new((n * 131 + m * 7) as u64);
            let x: Vec<f32> = (0..n)
                .map(|i| if i % 2 == 0 { 0.0 } else { rng.uniform(-2.0, 2.0) as f32 })
                .collect();
            let w: Vec<f32> = (0..n * m).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
            let mut acc = vec![0.5f64; m];
            let mut naive = acc.clone();
            accumulate_tiled(&x, &w, m, &mut acc);
            for (j, r) in naive.iter_mut().enumerate() {
                for i in 0..n {
                    *r += x[i] as f64 * w[i * m + j] as f64;
                }
            }
            assert_eq!(acc, naive, "n={n} m={m}");
        }
    }

    #[test]
    fn all_zero_inputs_leave_acc_untouched_even_with_remainders() {
        // both the panel skip and the remainder skip must fire
        for &(n, m) in &[(4usize, 3usize), (6, 5), (3, 4), (11, 7)] {
            let x = vec![0f32; n];
            let w: Vec<f32> = (0..n * m).map(|k| k as f32 - 1.5).collect();
            let mut acc: Vec<f64> = (0..m).map(|j| j as f64 + 0.25).collect();
            let before = acc.clone();
            accumulate_tiled(&x, &w, m, &mut acc);
            assert_eq!(acc, before, "n={n} m={m}");
        }
    }

    #[test]
    fn embed_row_on_zero_inputs_is_relu_bias() {
        // all-zero feature rows exercise the all-zero-panel path end to
        // end: the output must be exactly relu(bias)
        let mut rng = Rng::new(55);
        let inv = vec![0f32; INV_DIM];
        let dep = vec![0f32; DEP_DIM];
        let w_inv: Vec<f32> =
            (0..INV_DIM * EMB_INV).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let w_dep: Vec<f32> =
            (0..DEP_DIM * EMB_DEP).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let b_inv: Vec<f32> = (0..EMB_INV).map(|_| rng.uniform(-0.5, 0.5) as f32).collect();
        let b_dep: Vec<f32> = (0..EMB_DEP).map(|_| rng.uniform(-0.5, 0.5) as f32).collect();
        let mut out = vec![0f32; NODE_DIM];
        embed_row(&inv, &dep, &w_inv, &b_inv, &w_dep, &b_dep, &mut out);
        for j in 0..EMB_INV {
            assert_eq!(out[j], b_inv[j].max(0.0), "inv half at {j}");
        }
        for j in 0..EMB_DEP {
            assert_eq!(out[EMB_INV + j], b_dep[j].max(0.0), "dep half at {j}");
        }
    }

    #[test]
    fn conv_row_infer_matches_naive_reference() {
        // pin the fused gather+norm+scale/shift+relu row bitwise against
        // an independent naive recomputation on a real packed batch
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 3,
            schedules_per_pipeline: 2,
            seed: 29,
            ..Default::default()
        });
        let stats = ds.stats.clone().unwrap();
        let refs: Vec<&GraphSample> = ds.samples.iter().collect();
        let batch = PackedBatch::for_inference(&refs, &stats).unwrap();
        let nn = batch.total_nodes();
        let mut rng = Rng::new(4242);
        let t: Vec<f32> = (0..nn * NODE_DIM).map(|_| rng.uniform(-1.0, 1.0) as f32).collect();
        let bvec: Vec<f32> = (0..NODE_DIM).map(|_| rng.uniform(-0.5, 0.5) as f32).collect();
        let scale: Vec<f32> = (0..NODE_DIM).map(|_| rng.uniform(0.5, 1.5) as f32).collect();
        let shift: Vec<f32> = (0..NODE_DIM).map(|_| rng.uniform(-0.5, 0.5) as f32).collect();
        for node in 0..nn {
            let mut out = vec![0f32; NODE_DIM];
            conv_row_infer(&batch, &t, node, &bvec, &scale, &shift, &mut out);
            let (cols, vals) = batch.adj.row(node);
            let mut c = [0f64; NODE_DIM];
            for (&cix, &a) in cols.iter().zip(vals) {
                for j in 0..NODE_DIM {
                    c[j] += a as f64 * t[cix as usize * NODE_DIM + j] as f64;
                }
            }
            for j in 0..NODE_DIM {
                c[j] += bvec[j] as f64;
            }
            let mean = c.iter().sum::<f64>() / NODE_DIM as f64;
            let var =
                c.iter().map(|&x| (x - mean) * (x - mean)).sum::<f64>() / NODE_DIM as f64;
            let rs = 1.0 / (var + LN_EPS).sqrt();
            for j in 0..NODE_DIM {
                let hv = (c[j] - mean) * rs * scale[j] as f64 + shift[j] as f64;
                assert_eq!(out[j], hv.max(0.0) as f32, "node {node} j={j}");
            }
        }
    }
}
