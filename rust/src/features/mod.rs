//! Featurization (§II-C).
//!
//! Two per-stage feature families, mirroring the paper:
//!
//! * **schedule-invariant** ([`invariant`]) — operation histograms, access
//!   patterns, tensor geometry. Identical across all schedules of a pipeline.
//! * **schedule-dependent** ([`dependent`]) — loop extents after
//!   split/reorder, memory footprints vs the cache hierarchy, vector/scalar
//!   op counts, core utilization, inlining recompute, allocation and
//!   page-fault estimates — plus the **compound** products/ratios of [6]
//!   (arithmetic intensity, footprint/cache ratios, …) appended to the same
//!   vector.
//!
//! [`normalize`] computes dataset-wide mean/std so both the GCN and the
//! baselines see standardized inputs (§III-B: "we normalize the
//! schedule-invariant and dependent features over the entire training set").

pub mod invariant;
pub mod dependent;
pub mod normalize;

use crate::constants::{DEP_DIM, INV_DIM};
use crate::ir::pipeline::Pipeline;
use crate::lower::LoopNest;
use crate::schedule::primitives::PipelineSchedule;
use crate::sim::{analyze_pipeline, Machine};

/// Per-stage feature pair.
#[derive(Debug, Clone)]
pub struct StageFeatures {
    pub invariant: [f32; INV_DIM],
    pub dependent: [f32; DEP_DIM],
}

/// Featurize every stage of a scheduled pipeline.
pub fn featurize(
    p: &Pipeline,
    nests: &[LoopNest],
    sched: &PipelineSchedule,
    machine: &Machine,
) -> Vec<StageFeatures> {
    let analyses = analyze_pipeline(p, nests, sched, machine);
    let consumers = p.consumers();
    (0..p.num_stages())
        .map(|i| StageFeatures {
            invariant: invariant::invariant_features(p, &p.stages[i], &nests[i], &consumers[i]),
            dependent: dependent::dependent_features(
                &nests[i],
                &sched.stages[i],
                &analyses[i],
                machine,
            ),
        })
        .collect()
}

/// Schedule-invariant features only (extracted once per pipeline, at
/// ONNX→Halide conversion time in the paper's Fig 4 flow).
pub fn featurize_invariant(p: &Pipeline, nests: &[LoopNest]) -> Vec<[f32; INV_DIM]> {
    let consumers = p.consumers();
    (0..p.num_stages())
        .map(|i| invariant::invariant_features(p, &p.stages[i], &nests[i], &consumers[i]))
        .collect()
}

/// `log(1+x)` squashing used throughout (features span many decades).
#[inline]
pub(crate) fn l1p(x: f64) -> f32 {
    (x.max(0.0)).ln_1p() as f32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::onnx_gen::{generate_model, GenConfig};
    use crate::lower::lower_pipeline;
    use crate::schedule::random::random_pipeline_schedule;
    use crate::schedule::primitives::PipelineSchedule;
    use crate::util::propcheck;
    use crate::util::rng::Rng;

    #[test]
    fn invariant_features_are_schedule_invariant() {
        let cfg = GenConfig::default();
        let mut rng = Rng::new(21);
        let p = generate_model(&cfg, &mut rng, 0);
        let nests = lower_pipeline(&p);
        let m = Machine::default();
        let s1 = random_pipeline_schedule(&p, &nests, &mut rng);
        let s2 = random_pipeline_schedule(&p, &nests, &mut rng);
        let f1 = featurize(&p, &nests, &s1, &m);
        let f2 = featurize(&p, &nests, &s2, &m);
        for (a, b) in f1.iter().zip(&f2) {
            assert_eq!(a.invariant, b.invariant);
        }
    }

    #[test]
    fn dependent_features_react_to_schedule() {
        let cfg = GenConfig::default();
        let mut rng = Rng::new(22);
        let p = generate_model(&cfg, &mut rng, 0);
        let nests = lower_pipeline(&p);
        let m = Machine::default();
        let ranks: Vec<usize> = p.stages.iter().map(|s| s.shape.len()).collect();
        let default = PipelineSchedule::default_for(&ranks);
        let fd = featurize(&p, &nests, &default, &m);
        // find a random schedule that differs
        let mut found_diff = false;
        for _ in 0..8 {
            let s = random_pipeline_schedule(&p, &nests, &mut rng);
            let fs = featurize(&p, &nests, &s, &m);
            if fd.iter().zip(&fs).any(|(a, b)| a.dependent != b.dependent) {
                found_diff = true;
                break;
            }
        }
        assert!(found_diff, "dependent features never changed across schedules");
    }

    #[test]
    fn prop_features_finite() {
        propcheck::check_rng("features finite", 0xFEA7, 16, |rng| {
            let cfg = GenConfig::default();
            let p = generate_model(&cfg, rng, 0);
            let nests = lower_pipeline(&p);
            let m = Machine::default();
            let s = random_pipeline_schedule(&p, &nests, rng);
            for f in featurize(&p, &nests, &s, &m) {
                for (i, v) in f.invariant.iter().enumerate() {
                    if !v.is_finite() {
                        return Err(format!("invariant[{i}] = {v}"));
                    }
                }
                for (i, v) in f.dependent.iter().enumerate() {
                    if !v.is_finite() {
                        return Err(format!("dependent[{i}] = {v}"));
                    }
                }
            }
            Ok(())
        });
    }
}
