//! Schedule-invariant features (§II-C.1): "a histogram of different types of
//! operations performed … floating-point arithmetic … integer arithmetic used
//! for tensor indexing … boolean/logical operations … access patterns like
//! striding behavior, transposed access, and broadcasts."

use crate::constants::INV_DIM;
use crate::features::l1p;
use crate::ir::op::OpCategory;
use crate::ir::pipeline::{Pipeline, Stage};
use crate::lower::{AccessPattern, LoopNest};

/// Build the INV_DIM-wide schedule-invariant vector for one stage.
pub fn invariant_features(
    p: &Pipeline,
    stage: &Stage,
    nest: &LoopNest,
    consumers: &[usize],
) -> [f32; INV_DIM] {
    let mut f = [0f32; INV_DIM];
    let mut k = 0;
    let mut push = |v: f32| {
        f[k] = v;
        k += 1;
    };

    let points = nest.points();
    let red = nest.red_extent();

    // --- operation histogram (per point and totals, log-squashed) [14]
    push(l1p(nest.work.fmul));
    push(l1p(nest.work.fadd));
    push(l1p(nest.work.fdiv));
    push(l1p(nest.work.transcendental));
    push(l1p(nest.work.int_ops));
    push(l1p(nest.work.bool_ops));
    push(l1p(nest.work.cmp_ops));
    push(l1p(nest.work.fmul * points));
    push(l1p(nest.work.fadd * points));
    push(l1p(nest.work.fdiv * points));
    push(l1p(nest.work.transcendental * points));
    push(l1p(nest.work.int_ops * points));
    push(l1p(nest.work.bool_ops * points));
    push(l1p(nest.work.cmp_ops * points));

    // --- tensor geometry [8]
    push(l1p(points));
    push(l1p(red));
    push(nest.spatial.len() as f32);
    push(nest.reduction.len() as f32);
    push(l1p(nest.out_bytes));
    push(l1p(nest.total_flops()));
    push(l1p(nest.total_read_bytes()));
    push(if nest.pointwise { 1.0 } else { 0.0 });

    // --- access-pattern histogram over operands [5]
    let mut pat = [0f32; 5];
    for a in &nest.accesses {
        let idx = match a.pattern {
            AccessPattern::Contiguous => 0,
            AccessPattern::Strided(_) => 1,
            AccessPattern::Transposed => 2,
            AccessPattern::Broadcast => 3,
            AccessPattern::Stencil => 4,
        };
        pat[idx] += 1.0;
    }
    for v in pat {
        push(v);
    }

    // --- operand summary [4]
    push(stage.inputs.len() as f32);
    let weight_bufs = nest.accesses.iter().filter(|a| a.source.is_none()).count();
    push(weight_bufs as f32);
    let in_fp: f64 = nest.accesses.iter().map(|a| a.footprint_bytes).sum();
    push(l1p(in_fp));
    push(l1p(
        nest.accesses.iter().map(|a| a.footprint_bytes).fold(0.0, f64::max),
    ));

    // --- graph-local structure [3] (degree info also reaches the GCN via
    // the adjacency matrix; the FFN/GBT baselines only see it here)
    push(stage.inputs.len() as f32);
    push(consumers.len() as f32);
    push(stage.id as f32 / p.num_stages().max(1) as f32);

    // --- op category one-hot [9]
    let cat = stage.op.kind.category();
    let cats = [
        OpCategory::UnaryElementwise,
        OpCategory::BinaryElementwise,
        OpCategory::Logical,
        OpCategory::Conv,
        OpCategory::Matmul,
        OpCategory::Norm,
        OpCategory::Pool,
        OpCategory::Reduce,
        OpCategory::DataMovement,
    ];
    for c in cats {
        push(if cat == c { 1.0 } else { 0.0 });
    }

    // --- op attributes [5]
    let a = &stage.op.attrs;
    push((a.kernel.0 * a.kernel.1) as f32);
    push(a.stride as f32);
    push(a.pad as f32);
    push(l1p(a.out_channels as f64));
    push(if stage.op.kind.is_favored() { 1.0 } else { 0.0 });

    debug_assert!(k <= INV_DIM, "invariant features overflow: {k} > {INV_DIM}");
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Op, OpAttrs, OpKind};
    use crate::lower::lower_pipeline;

    #[test]
    fn feature_count_within_budget() {
        let mut p = Pipeline::new("t");
        let x = p.add_input(vec![1, 16, 32, 32]);
        let c = p.add_stage("conv", Op::new(OpKind::Conv2d), vec![x]).unwrap();
        p.add_stage("relu", Op::new(OpKind::Relu), vec![c]).unwrap();
        let nests = lower_pipeline(&p);
        let cons = p.consumers();
        let f = invariant_features(&p, &p.stages[0], &nests[0], &cons[0]);
        assert_eq!(f.len(), INV_DIM);
        assert!(f.iter().all(|v| v.is_finite()));
        // at least 30 of the 48 slots are populated for a conv
        assert!(f.iter().filter(|v| **v != 0.0).count() >= 20);
    }

    #[test]
    fn conv_vs_relu_distinguishable() {
        let mut p = Pipeline::new("t");
        let x = p.add_input(vec![1, 16, 32, 32]);
        let c = p.add_stage("conv", Op::new(OpKind::Conv2d), vec![x]).unwrap();
        p.add_stage("relu", Op::new(OpKind::Relu), vec![c]).unwrap();
        let nests = lower_pipeline(&p);
        let cons = p.consumers();
        let fc = invariant_features(&p, &p.stages[0], &nests[0], &cons[0]);
        let fr = invariant_features(&p, &p.stages[1], &nests[1], &cons[1]);
        assert_ne!(fc, fr);
    }

    #[test]
    fn gemm_histogram_scales_with_k() {
        let build = |k: usize| {
            let mut p = Pipeline::new("g");
            let x = p.add_input(vec![32, k]);
            let mut attrs = OpAttrs::default();
            attrs.out_channels = 16;
            p.add_stage("fc", Op::with_attrs(OpKind::Gemm, attrs), vec![x]).unwrap();
            let nests = lower_pipeline(&p);
            let cons = p.consumers();
            invariant_features(&p, &p.stages[0], &nests[0], &cons[0])
        };
        let small = build(64);
        let big = build(4096);
        // fmul per point grows with K
        assert!(big[0] > small[0]);
    }
}
