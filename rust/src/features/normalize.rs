//! Dataset-wide feature standardization (§III-B: features are normalized
//! over the entire training set before embedding).

use crate::constants::{DEP_DIM, INV_DIM};
use crate::features::StageFeatures;

/// Per-dimension mean/std for both feature families.
#[derive(Debug, Clone)]
pub struct FeatureStats {
    pub inv_mean: Vec<f64>,
    pub inv_std: Vec<f64>,
    pub dep_mean: Vec<f64>,
    pub dep_std: Vec<f64>,
}

impl FeatureStats {
    /// Accumulate stats from an iterator of stage features (Welford).
    pub fn fit<'a, I: IntoIterator<Item = &'a StageFeatures>>(features: I) -> FeatureStats {
        let mut n = 0f64;
        let mut inv_mean = vec![0f64; INV_DIM];
        let mut inv_m2 = vec![0f64; INV_DIM];
        let mut dep_mean = vec![0f64; DEP_DIM];
        let mut dep_m2 = vec![0f64; DEP_DIM];
        for f in features {
            n += 1.0;
            for (i, &x) in f.invariant.iter().enumerate() {
                let d = x as f64 - inv_mean[i];
                inv_mean[i] += d / n;
                inv_m2[i] += d * (x as f64 - inv_mean[i]);
            }
            for (i, &x) in f.dependent.iter().enumerate() {
                let d = x as f64 - dep_mean[i];
                dep_mean[i] += d / n;
                dep_m2[i] += d * (x as f64 - dep_mean[i]);
            }
        }
        assert!(n > 0.0, "FeatureStats::fit on empty input");
        let finish = |m2: Vec<f64>| -> Vec<f64> {
            m2.into_iter()
                .map(|v| {
                    let s = (v / n).sqrt();
                    if s < 1e-8 {
                        1.0 // constant feature: leave centered at 0
                    } else {
                        s
                    }
                })
                .collect()
        };
        FeatureStats {
            inv_mean,
            inv_std: finish(inv_m2),
            dep_mean,
            dep_std: finish(dep_m2),
        }
    }

    /// Standardize one stage's features in place.
    pub fn apply(&self, f: &mut StageFeatures) {
        for i in 0..INV_DIM {
            f.invariant[i] = ((f.invariant[i] as f64 - self.inv_mean[i]) / self.inv_std[i]) as f32;
        }
        for i in 0..DEP_DIM {
            f.dependent[i] = ((f.dependent[i] as f64 - self.dep_mean[i]) / self.dep_std[i]) as f32;
        }
    }

    /// Flat serialization (for the dataset store).
    pub fn to_flat(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(2 * (INV_DIM + DEP_DIM));
        v.extend(&self.inv_mean);
        v.extend(&self.inv_std);
        v.extend(&self.dep_mean);
        v.extend(&self.dep_std);
        v
    }

    pub fn from_flat(v: &[f64]) -> FeatureStats {
        assert_eq!(v.len(), 2 * (INV_DIM + DEP_DIM));
        FeatureStats {
            inv_mean: v[0..INV_DIM].to_vec(),
            inv_std: v[INV_DIM..2 * INV_DIM].to_vec(),
            dep_mean: v[2 * INV_DIM..2 * INV_DIM + DEP_DIM].to_vec(),
            dep_std: v[2 * INV_DIM + DEP_DIM..].to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(seed: f32) -> StageFeatures {
        let mut inv = [0f32; INV_DIM];
        let mut dep = [0f32; DEP_DIM];
        for i in 0..INV_DIM {
            inv[i] = seed * (i as f32 + 1.0);
        }
        for i in 0..DEP_DIM {
            dep[i] = -seed * (i as f32 + 1.0);
        }
        StageFeatures { invariant: inv, dependent: dep }
    }

    #[test]
    fn standardizes_to_zero_mean_unit_var() {
        let data: Vec<StageFeatures> = (0..100).map(|i| mk(i as f32 / 10.0)).collect();
        let stats = FeatureStats::fit(data.iter());
        let mut sum = vec![0f64; INV_DIM];
        let mut sq = vec![0f64; INV_DIM];
        for f in &data {
            let mut g = f.clone();
            stats.apply(&mut g);
            for i in 0..INV_DIM {
                sum[i] += g.invariant[i] as f64;
                sq[i] += (g.invariant[i] as f64).powi(2);
            }
        }
        for i in 0..INV_DIM {
            let mean = sum[i] / 100.0;
            let var = sq[i] / 100.0 - mean * mean;
            assert!(mean.abs() < 1e-4, "dim {i} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "dim {i} var {var}");
        }
    }

    #[test]
    fn constant_features_stay_finite() {
        let data: Vec<StageFeatures> = (0..10).map(|_| mk(0.0)).collect();
        let stats = FeatureStats::fit(data.iter());
        let mut g = data[0].clone();
        stats.apply(&mut g);
        assert!(g.invariant.iter().all(|v| v.is_finite()));
        assert!(g.dependent.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn flat_roundtrip() {
        let data: Vec<StageFeatures> = (0..10).map(|i| mk(i as f32)).collect();
        let stats = FeatureStats::fit(data.iter());
        let rt = FeatureStats::from_flat(&stats.to_flat());
        assert_eq!(stats.inv_mean, rt.inv_mean);
        assert_eq!(stats.dep_std, rt.dep_std);
    }
}
