//! Dataset-wide feature standardization (§III-B: features are normalized
//! over the entire training set before embedding).

use crate::constants::{DEP_DIM, INV_DIM};
use crate::features::StageFeatures;

/// Per-dimension mean/std for both feature families.
#[derive(Debug, Clone)]
pub struct FeatureStats {
    pub inv_mean: Vec<f64>,
    pub inv_std: Vec<f64>,
    pub dep_mean: Vec<f64>,
    pub dep_std: Vec<f64>,
}

/// Incremental Welford accumulator behind [`FeatureStats::fit`].
///
/// Exposed so streaming consumers ([`crate::dataset::shard`]'s corpus
/// writer) can fold stage features in sample-at-a-time without holding
/// the corpus in RAM. Pushing the same features in the same order
/// produces bitwise-identical stats to the one-shot `fit` — `fit` is a
/// thin loop over [`StatsAccumulator::push`].
#[derive(Debug, Clone)]
pub struct StatsAccumulator {
    n: f64,
    inv_mean: Vec<f64>,
    inv_m2: Vec<f64>,
    dep_mean: Vec<f64>,
    dep_m2: Vec<f64>,
}

impl Default for StatsAccumulator {
    fn default() -> Self {
        StatsAccumulator::new()
    }
}

impl StatsAccumulator {
    pub fn new() -> StatsAccumulator {
        StatsAccumulator {
            n: 0.0,
            inv_mean: vec![0f64; INV_DIM],
            inv_m2: vec![0f64; INV_DIM],
            dep_mean: vec![0f64; DEP_DIM],
            dep_m2: vec![0f64; DEP_DIM],
        }
    }

    /// Fold one stage's raw feature rows into the running moments.
    pub fn push(&mut self, invariant: &[f32; INV_DIM], dependent: &[f32; DEP_DIM]) {
        self.n += 1.0;
        for (i, &x) in invariant.iter().enumerate() {
            let d = x as f64 - self.inv_mean[i];
            self.inv_mean[i] += d / self.n;
            self.inv_m2[i] += d * (x as f64 - self.inv_mean[i]);
        }
        for (i, &x) in dependent.iter().enumerate() {
            let d = x as f64 - self.dep_mean[i];
            self.dep_mean[i] += d / self.n;
            self.dep_m2[i] += d * (x as f64 - self.dep_mean[i]);
        }
    }

    /// Stages folded so far.
    pub fn count(&self) -> usize {
        self.n as usize
    }

    /// Finalize into mean/std. Panics on an empty accumulator, matching
    /// the historical `fit` contract.
    pub fn finish(self) -> FeatureStats {
        let n = self.n;
        assert!(n > 0.0, "FeatureStats::fit on empty input");
        let finish = |m2: Vec<f64>| -> Vec<f64> {
            m2.into_iter()
                .map(|v| {
                    let s = (v / n).sqrt();
                    if s < 1e-8 {
                        1.0 // constant feature: leave centered at 0
                    } else {
                        s
                    }
                })
                .collect()
        };
        FeatureStats {
            inv_mean: self.inv_mean,
            inv_std: finish(self.inv_m2),
            dep_mean: self.dep_mean,
            dep_std: finish(self.dep_m2),
        }
    }
}

impl FeatureStats {
    /// Accumulate stats from an iterator of stage features (Welford).
    pub fn fit<'a, I: IntoIterator<Item = &'a StageFeatures>>(features: I) -> FeatureStats {
        let mut acc = StatsAccumulator::new();
        for f in features {
            acc.push(&f.invariant, &f.dependent);
        }
        acc.finish()
    }

    /// Standardize one stage's features in place.
    pub fn apply(&self, f: &mut StageFeatures) {
        for i in 0..INV_DIM {
            f.invariant[i] = ((f.invariant[i] as f64 - self.inv_mean[i]) / self.inv_std[i]) as f32;
        }
        for i in 0..DEP_DIM {
            f.dependent[i] = ((f.dependent[i] as f64 - self.dep_mean[i]) / self.dep_std[i]) as f32;
        }
    }

    /// Flat serialization (for the dataset store).
    pub fn to_flat(&self) -> Vec<f64> {
        let mut v = Vec::with_capacity(2 * (INV_DIM + DEP_DIM));
        v.extend(&self.inv_mean);
        v.extend(&self.inv_std);
        v.extend(&self.dep_mean);
        v.extend(&self.dep_std);
        v
    }

    pub fn from_flat(v: &[f64]) -> FeatureStats {
        assert_eq!(v.len(), 2 * (INV_DIM + DEP_DIM));
        FeatureStats {
            inv_mean: v[0..INV_DIM].to_vec(),
            inv_std: v[INV_DIM..2 * INV_DIM].to_vec(),
            dep_mean: v[2 * INV_DIM..2 * INV_DIM + DEP_DIM].to_vec(),
            dep_std: v[2 * INV_DIM + DEP_DIM..].to_vec(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(seed: f32) -> StageFeatures {
        let mut inv = [0f32; INV_DIM];
        let mut dep = [0f32; DEP_DIM];
        for i in 0..INV_DIM {
            inv[i] = seed * (i as f32 + 1.0);
        }
        for i in 0..DEP_DIM {
            dep[i] = -seed * (i as f32 + 1.0);
        }
        StageFeatures { invariant: inv, dependent: dep }
    }

    #[test]
    fn standardizes_to_zero_mean_unit_var() {
        let data: Vec<StageFeatures> = (0..100).map(|i| mk(i as f32 / 10.0)).collect();
        let stats = FeatureStats::fit(data.iter());
        let mut sum = vec![0f64; INV_DIM];
        let mut sq = vec![0f64; INV_DIM];
        for f in &data {
            let mut g = f.clone();
            stats.apply(&mut g);
            for i in 0..INV_DIM {
                sum[i] += g.invariant[i] as f64;
                sq[i] += (g.invariant[i] as f64).powi(2);
            }
        }
        for i in 0..INV_DIM {
            let mean = sum[i] / 100.0;
            let var = sq[i] / 100.0 - mean * mean;
            assert!(mean.abs() < 1e-4, "dim {i} mean {mean}");
            assert!((var - 1.0).abs() < 1e-2, "dim {i} var {var}");
        }
    }

    #[test]
    fn constant_features_stay_finite() {
        let data: Vec<StageFeatures> = (0..10).map(|_| mk(0.0)).collect();
        let stats = FeatureStats::fit(data.iter());
        let mut g = data[0].clone();
        stats.apply(&mut g);
        assert!(g.invariant.iter().all(|v| v.is_finite()));
        assert!(g.dependent.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn incremental_accumulator_matches_fit_bitwise() {
        let data: Vec<StageFeatures> = (0..37).map(|i| mk(i as f32 * 0.3 - 2.0)).collect();
        let one_shot = FeatureStats::fit(data.iter());
        let mut acc = StatsAccumulator::new();
        for f in &data {
            acc.push(&f.invariant, &f.dependent);
        }
        assert_eq!(acc.count(), 37);
        let streamed = acc.finish();
        // identical op order => bitwise-identical moments
        assert_eq!(one_shot.to_flat(), streamed.to_flat());
    }

    #[test]
    fn flat_roundtrip() {
        let data: Vec<StageFeatures> = (0..10).map(|i| mk(i as f32)).collect();
        let stats = FeatureStats::fit(data.iter());
        let rt = FeatureStats::from_flat(&stats.to_flat());
        assert_eq!(stats.inv_mean, rt.inv_mean);
        assert_eq!(stats.dep_std, rt.dep_std);
    }
}
