//! Schedule-dependent features (§II-C.2) + compound features ([6], §II-C
//! "Compound Features"), concatenated into one DEP_DIM vector.

use crate::constants::DEP_DIM;
use crate::features::l1p;
use crate::lower::LoopNest;
use crate::schedule::primitives::{ComputeLoc, StageSchedule};
use crate::sim::analysis::{Level, StageAnalysis};
use crate::sim::Machine;

/// Build the DEP_DIM-wide schedule-dependent (+compound) vector.
pub fn dependent_features(
    nest: &LoopNest,
    sched: &StageSchedule,
    an: &StageAnalysis,
    m: &Machine,
) -> [f32; DEP_DIM] {
    let mut f = [0f32; DEP_DIM];
    let mut k = 0;
    let mut push = |v: f32| {
        f[k] = v;
        k += 1;
    };

    // --- loop structure after split/reorder [12]
    let extents = sched.loop_extents(&nest.spatial);
    for i in 0..8 {
        push(extents.get(i).map(|&e| l1p(e as f64)).unwrap_or(0.0));
    }
    push(extents.len() as f32);
    push(if sched.is_tiled() { 1.0 } else { 0.0 });
    let natural = sched.order.iter().enumerate().all(|(i, &d)| i == d);
    push(if natural { 1.0 } else { 0.0 });
    push(l1p(nest.red_extent()));

    // --- tiling factors [4]
    for i in 0..4 {
        push(sched.tile.get(i).map(|&t| l1p(t as f64)).unwrap_or(0.0));
    }

    // --- vectorization (§II-C.2: vectorized vs scalar op counts) [6]
    let vec_on = an.vector_width > 1;
    push(an.vector_width as f32);
    push(if vec_on { 1.0 } else { 0.0 });
    let flops_total = an.work.total_flops() * an.points;
    push(l1p(if vec_on { flops_total } else { 0.0 })); // vector fp ops
    push(l1p(if vec_on { 0.0 } else { flops_total })); // scalar fp ops
    let int_total = (an.work.int_ops + an.work.cmp_ops + an.work.bool_ops) * an.points;
    push(l1p(if vec_on { int_total } else { 0.0 }));
    push(l1p(if vec_on { 0.0 } else { int_total }));

    // --- parallelism (core utilization ratio) [4]
    push(l1p(an.parallel_tasks as f64));
    push((an.parallel_tasks.min(m.cores)) as f32 / m.cores as f32);
    push(sched.parallel_depth as f32);
    let waves = (an.parallel_tasks as f64 / m.cores as f64).ceil().max(1.0);
    push((an.parallel_tasks as f64 / (waves * m.cores as f64)) as f32); // imbalance eff.

    // --- unrolling [2]
    push(sched.unroll as f32);
    push(l1p(an.inner_iters));

    // --- compute location & inlining recompute [6]
    push(matches!(sched.compute, ComputeLoc::Root) as i32 as f32);
    push(matches!(sched.compute, ComputeLoc::At { .. }) as i32 as f32);
    push(matches!(sched.compute, ComputeLoc::Inline) as i32 as f32);
    push(match sched.compute {
        ComputeLoc::At { level, .. } => level as f32,
        _ => 0.0,
    });
    push(an.recompute as f32);
    push(l1p((an.recompute - 1.0).max(0.0) * nest.points() * an.work.total_flops()));

    // --- memory footprint vs hierarchy (§II-C.2: unique cache lines,
    // accessed bytes, reuse distance proxies) [10]
    push(l1p(an.footprint));
    push(l1p(an.footprint / 64.0)); // unique cache lines
    push(l1p(an.tile_ws));
    push(if an.tile_ws <= m.l1_bytes { 1.0 } else { 0.0 });
    push(if an.tile_ws <= m.l2_bytes { 1.0 } else { 0.0 });
    push(if an.tile_ws <= m.llc_bytes { 1.0 } else { 0.0 });
    let cold: f64 = an.traffic.iter().map(|t| t.cold_bytes).sum();
    let reuse: f64 = an.traffic.iter().map(|t| t.reuse_bytes).sum();
    push(l1p(cold));
    push(l1p(reuse));
    let min_util = an
        .traffic
        .iter()
        .map(|t| t.line_utilization)
        .fold(1.0, f64::min);
    push(min_util as f32);
    push(l1p(an.out_bytes));

    // --- traffic by serving level (reuse-distance histogram analogue) [8]
    let mut by_level = [0f64; 4];
    for t in &an.traffic {
        let li = |l: Level| match l {
            Level::L1 => 0,
            Level::L2 => 1,
            Level::Llc => 2,
            Level::Dram => 3,
        };
        by_level[li(t.cold_level)] += t.cold_bytes;
        by_level[li(t.reuse_level)] += t.reuse_bytes;
    }
    for b in by_level {
        push(l1p(b));
    }
    push(match an.out_level {
        Level::L1 => 0.0,
        Level::L2 => 1.0,
        Level::Llc => 2.0,
        Level::Dram => 3.0,
    });
    push(l1p(an.points));
    push(if an.inlined { 1.0 } else { 0.0 });
    push(l1p(an.work.total_flops()));

    // --- allocation / system overheads (§II-C.2: heap allocations, context
    // switches, page faults) [6]
    push(l1p(an.alloc_bytes));
    push(if an.alloc_bytes > 0.0 { 1.0 } else { 0.0 });
    push(l1p(an.page_faults));
    push(l1p(an.parallel_tasks as f64 * m.task_overhead_s * 1e9)); // dispatch ns
    push(l1p(an.alloc_bytes / 4096.0)); // pages
    push((an.parallel_tasks > m.cores) as i32 as f32); // oversubscription

    // ===== compound features [remaining slots] — products & ratios that a
    // small network struggles to synthesize (§II-C "Compound Features").
    let bytes_total = cold + reuse + an.out_bytes;
    let ai = flops_total / bytes_total.max(1.0); // arithmetic intensity
    push(l1p(ai));
    push(l1p(flops_total / m.cores as f64));
    push(l1p(bytes_total / m.cores as f64));
    push(l1p(an.points / an.parallel_tasks.max(1) as f64)); // points per task
    push(l1p(an.footprint / m.llc_bytes));
    push(l1p(an.footprint / m.l2_bytes));
    push(l1p(an.tile_ws / m.l1_bytes));
    push(l1p(an.tile_ws / m.l2_bytes));
    push(l1p(cold / min_util.max(1e-3))); // line-inflated cold traffic
    push(l1p(an.page_faults * m.page_fault_s * 1e9));
    push(l1p(flops_total / m.vec_flops_per_cycle / m.freq_hz * 1e9)); // ideal vec ns
    push(l1p(flops_total / m.scalar_flops_per_cycle / m.freq_hz * 1e9)); // ideal scalar ns
    push(l1p(bytes_total / m.dram_bw * 1e9)); // dram-bound ns
    push(l1p(an.inner_iters * 2.0 / m.freq_hz * 1e9)); // loop overhead ns
    push(l1p(an.recompute * an.points * an.work.total_flops() / m.vec_flops_per_cycle));
    push((an.vector_width as f64 / m.simd_lanes as f64) as f32);
    push(l1p(reuse / an.footprint.max(1.0))); // reuse ratio
    push(l1p(an.out_bytes / 4096.0));
    push(ai.min(100.0) as f32 / 100.0);
    push(l1p((an.work.transcendental * an.points) * 16.0 / m.freq_hz * 1e9));
    push(l1p((an.work.fdiv * an.points) * 8.0 / m.freq_hz * 1e9));
    push(l1p(bytes_total));

    drop(push);
    debug_assert!(k <= DEP_DIM, "dependent features overflow: {k} > {DEP_DIM}");
    f
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Op, OpAttrs, OpKind};
    use crate::ir::pipeline::Pipeline;
    use crate::lower::lower_pipeline;
    use crate::schedule::primitives::PipelineSchedule;
    use crate::sim::analyze_pipeline;

    fn setup() -> (Pipeline, Vec<LoopNest>) {
        let mut p = Pipeline::new("t");
        let x = p.add_input(vec![1, 16, 64, 64]);
        let mut attrs = OpAttrs::default();
        attrs.out_channels = 32;
        let c = p.add_stage("conv", Op::with_attrs(OpKind::Conv2d, attrs), vec![x]).unwrap();
        p.add_stage("relu", Op::new(OpKind::Relu), vec![c]).unwrap();
        let nests = lower_pipeline(&p);
        (p, nests)
    }

    #[test]
    fn vectorization_flips_vector_scalar_slots() {
        let (p, nests) = setup();
        let m = Machine::default();
        let mut sched = PipelineSchedule::default_for(&[4, 4]);
        let an = analyze_pipeline(&p, &nests, &sched, &m);
        let scalar = dependent_features(&nests[0], &sched.stages[0], &an[0], &m);
        sched.stages[0].vector_width = 8;
        let an = analyze_pipeline(&p, &nests, &sched, &m);
        let vec = dependent_features(&nests[0], &sched.stages[0], &an[0], &m);
        assert_ne!(scalar, vec);
        // slot 16 is vector_width
        assert_eq!(scalar[16], 1.0);
        assert_eq!(vec[16], 8.0);
    }

    #[test]
    fn parallel_ratio_capped_at_one() {
        let (p, nests) = setup();
        let m = Machine::default();
        let mut sched = PipelineSchedule::default_for(&[4, 4]);
        sched.stages[0].order = vec![1, 2, 3, 0];
        sched.stages[0].parallel_depth = 2;
        let an = analyze_pipeline(&p, &nests, &sched, &m);
        let f = dependent_features(&nests[0], &sched.stages[0], &an[0], &m);
        // core utilization ratio slot (index 23) in (0,1]
        assert!(f[23] > 0.0 && f[23] <= 1.0, "{}", f[23]);
    }

    #[test]
    fn all_finite_for_default_schedule() {
        let (p, nests) = setup();
        let m = Machine::default();
        let sched = PipelineSchedule::default_for(&[4, 4]);
        let an = analyze_pipeline(&p, &nests, &sched, &m);
        for i in 0..2 {
            let f = dependent_features(&nests[i], &sched.stages[i], &an[i], &m);
            assert!(f.iter().all(|v| v.is_finite()));
        }
    }
}
