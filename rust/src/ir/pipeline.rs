//! The stage DAG.

use crate::analysis::diag::{Code, Diagnostic};
use crate::ir::op::Op;
use crate::ir::tensor::Shape;
use std::collections::BTreeSet;

/// Where a stage's operand comes from.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum SourceRef {
    /// Pipeline input tensor (an `ImageParam` in Halide terms).
    Input(usize),
    /// Output of an earlier stage.
    Stage(usize),
}

/// One computational stage — the analogue of a Halide `Func`.
#[derive(Debug, Clone)]
pub struct Stage {
    pub id: usize,
    pub name: String,
    pub op: Op,
    pub inputs: Vec<SourceRef>,
    /// Inferred output shape.
    pub shape: Shape,
}

/// A pipeline: input tensors plus a topologically ordered list of stages.
#[derive(Debug, Clone, Default)]
pub struct Pipeline {
    pub name: String,
    /// Shapes of the pipeline input tensors.
    pub inputs: Vec<Shape>,
    pub stages: Vec<Stage>,
}

impl Pipeline {
    pub fn new(name: &str) -> Self {
        Pipeline { name: name.to_string(), inputs: vec![], stages: vec![] }
    }

    /// Register a pipeline input tensor, returning its `SourceRef`.
    pub fn add_input(&mut self, shape: Shape) -> SourceRef {
        self.inputs.push(shape);
        SourceRef::Input(self.inputs.len() - 1)
    }

    /// Append a stage; operand shapes must be compatible with `op`.
    ///
    /// On failure the [`Diagnostic`] carries the would-be stage id, the op
    /// kind, and the offending arity or operand shapes (`A001`/`A002`/
    /// `A003`/`A005`), so callers can report *why* construction failed
    /// instead of a bare `None`.
    pub fn add_stage(
        &mut self,
        name: &str,
        op: Op,
        inputs: Vec<SourceRef>,
    ) -> Result<SourceRef, Diagnostic> {
        let id = self.stages.len();
        let opname = op.kind.name();
        if inputs.len() != op.kind.graph_arity() {
            return Err(Diagnostic::at_stage(
                Code::ArityMismatch,
                id,
                opname,
                format!("arity {} != expected {}", inputs.len(), op.kind.graph_arity()),
            ));
        }
        for &inp in &inputs {
            match inp {
                SourceRef::Input(i) if i >= self.inputs.len() => {
                    return Err(Diagnostic::at_stage(
                        Code::DanglingInputRef,
                        id,
                        opname,
                        format!("dangling input ref {i} (pipeline has {})", self.inputs.len()),
                    ));
                }
                SourceRef::Stage(i) if i >= id => {
                    return Err(Diagnostic::at_stage(
                        Code::ForwardStageRef,
                        id,
                        opname,
                        format!("forward/self reference to stage {i}"),
                    ));
                }
                _ => {}
            }
        }
        let shapes: Vec<&[usize]> = inputs.iter().map(|s| self.shape_of(*s)).collect();
        let Some(out) = op.infer_shape(&shapes) else {
            return Err(Diagnostic::at_stage(
                Code::ShapeInferenceFailed,
                id,
                opname,
                format!("shape inference fails on operand shapes {shapes:?}"),
            ));
        };
        self.stages.push(Stage {
            id,
            name: name.to_string(),
            op,
            inputs,
            shape: out,
        });
        Ok(SourceRef::Stage(id))
    }

    pub fn shape_of(&self, src: SourceRef) -> &[usize] {
        match src {
            SourceRef::Input(i) => &self.inputs[i],
            SourceRef::Stage(i) => &self.stages[i].shape,
        }
    }

    pub fn num_stages(&self) -> usize {
        self.stages.len()
    }

    /// Stage ids with no stage consumers (pipeline outputs).
    pub fn outputs(&self) -> Vec<usize> {
        let mut consumed = BTreeSet::new();
        for s in &self.stages {
            for &inp in &s.inputs {
                if let SourceRef::Stage(i) = inp {
                    consumed.insert(i);
                }
            }
        }
        (0..self.stages.len()).filter(|i| !consumed.contains(i)).collect()
    }

    /// For each stage, the list of stage ids that consume it.
    pub fn consumers(&self) -> Vec<Vec<usize>> {
        let mut cons = vec![Vec::new(); self.stages.len()];
        for s in &self.stages {
            for &inp in &s.inputs {
                if let SourceRef::Stage(i) = inp {
                    cons[i].push(s.id);
                }
            }
        }
        cons
    }

    /// Directed adjacency matrix over stages: `adj[i][j] = 1` iff stage i
    /// feeds stage j. (The GCN symmetrizes + row-normalizes this.)
    pub fn adjacency(&self) -> Vec<Vec<f32>> {
        let n = self.stages.len();
        let mut adj = vec![vec![0.0; n]; n];
        for s in &self.stages {
            for &inp in &s.inputs {
                if let SourceRef::Stage(i) = inp {
                    adj[i][s.id] = 1.0;
                }
            }
        }
        adj
    }

    /// Longest path length (in stages) from any source stage to any output —
    /// the paper's `depth` filter (§III-A, `depth_thresh = 5`).
    pub fn depth(&self) -> usize {
        let mut d = vec![1usize; self.stages.len()];
        for s in &self.stages {
            for &inp in &s.inputs {
                if let SourceRef::Stage(i) = inp {
                    d[s.id] = d[s.id].max(d[i] + 1);
                }
            }
        }
        d.into_iter().max().unwrap_or(0)
    }

    /// Structural validation: topological ordering, arity, shape inference
    /// consistency. Returns a description of the first violation.
    pub fn validate(&self) -> Result<(), String> {
        for s in &self.stages {
            if s.inputs.len() != s.op.kind.graph_arity() {
                return Err(format!(
                    "stage {} ({}): arity {} != expected {}",
                    s.id,
                    s.op.kind.name(),
                    s.inputs.len(),
                    s.op.kind.graph_arity()
                ));
            }
            for &inp in &s.inputs {
                match inp {
                    SourceRef::Input(i) if i >= self.inputs.len() => {
                        return Err(format!("stage {}: dangling input ref {}", s.id, i));
                    }
                    SourceRef::Stage(i) if i >= s.id => {
                        return Err(format!(
                            "stage {}: forward/self reference to stage {}",
                            s.id, i
                        ));
                    }
                    _ => {}
                }
            }
            let shapes: Vec<&[usize]> = s.inputs.iter().map(|&x| self.shape_of(x)).collect();
            match s.op.infer_shape(&shapes) {
                Some(sh) if sh == s.shape => {}
                Some(sh) => {
                    return Err(format!(
                        "stage {}: stored shape {:?} != inferred {:?}",
                        s.id, s.shape, sh
                    ));
                }
                None => return Err(format!("stage {}: shape inference fails", s.id)),
            }
        }
        Ok(())
    }

    /// Total f32 elements across all stage output buffers.
    pub fn total_elems(&self) -> usize {
        self.stages.iter().map(|s| s.shape.iter().product::<usize>()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Op, OpAttrs, OpKind};

    /// The paper's §II example: linear layer = matmul + bias add.
    fn linear_pipeline() -> Pipeline {
        let mut p = Pipeline::new("linear");
        let x = p.add_input(vec![64, 1024]);
        let b = p.add_input(vec![64, 16]);
        let mut gemm = OpAttrs::default();
        gemm.out_channels = 16;
        let mm = p
            .add_stage("matrix_mul", Op::with_attrs(OpKind::Gemm, gemm), vec![x])
            .unwrap();
        p.add_stage("add_bias", Op::new(OpKind::Add), vec![mm, b]).unwrap();
        p
    }

    #[test]
    fn linear_layer_builds_and_validates() {
        let p = linear_pipeline();
        assert_eq!(p.num_stages(), 2);
        assert_eq!(p.stages[1].shape, vec![64, 16]);
        p.validate().unwrap();
        assert_eq!(p.outputs(), vec![1]);
        assert_eq!(p.depth(), 2);
    }

    #[test]
    fn adjacency_matches_edges() {
        let p = linear_pipeline();
        let adj = p.adjacency();
        assert_eq!(adj[0][1], 1.0);
        assert_eq!(adj[1][0], 0.0);
        assert_eq!(adj[0][0], 0.0);
    }

    #[test]
    fn incompatible_stage_rejected() {
        let mut p = Pipeline::new("bad");
        let x = p.add_input(vec![2, 3]);
        let y = p.add_input(vec![4, 5]);
        let err = p.add_stage("a", Op::new(OpKind::Add), vec![x, y]).unwrap_err();
        assert_eq!(err.code, Code::ShapeInferenceFailed);
        assert_eq!(p.num_stages(), 0);
    }

    #[test]
    fn add_stage_rejects_bad_refs_with_codes() {
        let mut p = Pipeline::new("bad");
        let x = p.add_input(vec![2, 3]);
        let err = p.add_stage("a", Op::new(OpKind::Add), vec![x]).unwrap_err();
        assert_eq!(err.code, Code::ArityMismatch);
        let err =
            p.add_stage("b", Op::new(OpKind::Relu), vec![SourceRef::Input(7)]).unwrap_err();
        assert_eq!(err.code, Code::DanglingInputRef);
        let err =
            p.add_stage("c", Op::new(OpKind::Relu), vec![SourceRef::Stage(0)]).unwrap_err();
        assert_eq!(err.code, Code::ForwardStageRef);
        assert_eq!(p.num_stages(), 0);
        // the diagnostic renders with code + location
        assert!(err.to_string().contains("A003"), "{err}");
    }

    #[test]
    fn consumers_and_outputs() {
        let mut p = Pipeline::new("diamond");
        let x = p.add_input(vec![1, 8, 16, 16]);
        let r = p.add_stage("relu", Op::new(OpKind::Relu), vec![x]).unwrap();
        let a = p.add_stage("exp", Op::new(OpKind::Exp), vec![r]).unwrap();
        let b = p.add_stage("abs", Op::new(OpKind::Abs), vec![r]).unwrap();
        p.add_stage("add", Op::new(OpKind::Add), vec![a, b]).unwrap();
        let cons = p.consumers();
        assert_eq!(cons[0], vec![1, 2]);
        assert_eq!(p.outputs(), vec![3]);
        assert_eq!(p.depth(), 3);
        p.validate().unwrap();
    }

    #[test]
    fn validate_catches_corruption() {
        let mut p = linear_pipeline();
        p.stages[1].shape = vec![9, 9];
        assert!(p.validate().is_err());
    }
}
