//! Tensor operators.
//!
//! The set mirrors the ~50 ONNX operators the paper's random model generator
//! draws from (§III-A: "Gemm, Conv, Maxpool, Average Pool, Relu, Sigmoid,
//! Softmax, etc. We have identified about 50 such operators").

use crate::ir::tensor::{broadcast, Shape};

/// Operator kinds. Grouped by [`OpCategory`]; see [`OpKind::category`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
#[rustfmt::skip]
pub enum OpKind {
    // -- unary elementwise (transcendental-heavy ones flagged in work cost)
    Relu, LeakyRelu, Elu, Sigmoid, Tanh, Softplus, Gelu, HardSwish, Erf,
    Exp, Log, Sqrt, Reciprocal, Abs, Neg, Floor, Ceil, Round, Sign, Clip,
    // -- binary elementwise
    Add, Sub, Mul, Div, Pow, Min, Max, PRelu,
    // -- logical / comparison (boolean outputs kept as f32 0/1)
    And, Or, Xor, Not, Greater, Less, Equal, Where,
    // -- weight-bearing layers (weights are implicit parameter buffers)
    Conv2d, DepthwiseConv2d, Gemm, MatMul, BatchNorm, LayerNorm, InstanceNorm,
    // -- pooling / reductions
    MaxPool, AveragePool, GlobalAveragePool, ReduceMean, ReduceSum, ReduceMax,
    Softmax, LogSoftmax,
    // -- data movement / shape
    Pad, Concat, Slice, Transpose, Reshape, Flatten, Upsample, Identity,
}

/// Coarse operator family — drives lowering, featurization histograms and the
/// generator's unary/binary sampling (Algorithm 1 `node.type`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum OpCategory {
    UnaryElementwise,
    BinaryElementwise,
    Logical,
    Conv,
    Matmul,
    Norm,
    Pool,
    Reduce,
    DataMovement,
}

impl OpKind {
    pub const ALL: &'static [OpKind] = &[
        OpKind::Relu, OpKind::LeakyRelu, OpKind::Elu, OpKind::Sigmoid, OpKind::Tanh,
        OpKind::Softplus, OpKind::Gelu, OpKind::HardSwish, OpKind::Erf, OpKind::Exp,
        OpKind::Log, OpKind::Sqrt, OpKind::Reciprocal, OpKind::Abs, OpKind::Neg,
        OpKind::Floor, OpKind::Ceil, OpKind::Round, OpKind::Sign, OpKind::Clip,
        OpKind::Add, OpKind::Sub, OpKind::Mul, OpKind::Div, OpKind::Pow,
        OpKind::Min, OpKind::Max, OpKind::PRelu,
        OpKind::And, OpKind::Or, OpKind::Xor, OpKind::Not, OpKind::Greater,
        OpKind::Less, OpKind::Equal, OpKind::Where,
        OpKind::Conv2d, OpKind::DepthwiseConv2d, OpKind::Gemm, OpKind::MatMul,
        OpKind::BatchNorm, OpKind::LayerNorm, OpKind::InstanceNorm,
        OpKind::MaxPool, OpKind::AveragePool, OpKind::GlobalAveragePool,
        OpKind::ReduceMean, OpKind::ReduceSum, OpKind::ReduceMax,
        OpKind::Softmax, OpKind::LogSoftmax,
        OpKind::Pad, OpKind::Concat, OpKind::Slice, OpKind::Transpose,
        OpKind::Reshape, OpKind::Flatten, OpKind::Upsample, OpKind::Identity,
    ];

    pub fn category(self) -> OpCategory {
        use OpCategory::*;
        use OpKind::*;
        match self {
            Relu | LeakyRelu | Elu | Sigmoid | Tanh | Softplus | Gelu | HardSwish | Erf
            | Exp | Log | Sqrt | Reciprocal | Abs | Neg | Floor | Ceil | Round | Sign
            | Clip => UnaryElementwise,
            Add | Sub | Mul | Div | Pow | Min | Max | PRelu => BinaryElementwise,
            And | Or | Xor | Not | Greater | Less | Equal | Where => Logical,
            Conv2d | DepthwiseConv2d => Conv,
            Gemm | MatMul => Matmul,
            BatchNorm | LayerNorm | InstanceNorm => Norm,
            MaxPool | AveragePool | GlobalAveragePool => Pool,
            ReduceMean | ReduceSum | ReduceMax | Softmax | LogSoftmax => Reduce,
            Pad | Concat | Slice | Transpose | Reshape | Flatten | Upsample | Identity => {
                DataMovement
            }
        }
    }

    /// Number of *tensor* operands flowing through the graph (weights are
    /// implicit parameters, not graph edges — they become extra buffers in
    /// lowering and featurization).
    pub fn graph_arity(self) -> usize {
        use OpKind::*;
        match self {
            Add | Sub | Mul | Div | Pow | Min | Max | PRelu | And | Or | Xor | Greater
            | Less | Equal | Concat | MatMul => 2,
            Where => 3,
            _ => 1,
        }
    }

    /// Ops the paper's filter favors (§III-A `favored_ops = {conv, relu, ...}`).
    pub fn is_favored(self) -> bool {
        use OpKind::*;
        matches!(
            self,
            Conv2d | DepthwiseConv2d | Gemm | Relu | MaxPool | AveragePool | BatchNorm | Softmax
        )
    }

    pub fn name(self) -> &'static str {
        use OpKind::*;
        match self {
            Relu => "Relu", LeakyRelu => "LeakyRelu", Elu => "Elu", Sigmoid => "Sigmoid",
            Tanh => "Tanh", Softplus => "Softplus", Gelu => "Gelu", HardSwish => "HardSwish",
            Erf => "Erf", Exp => "Exp", Log => "Log", Sqrt => "Sqrt",
            Reciprocal => "Reciprocal", Abs => "Abs", Neg => "Neg", Floor => "Floor",
            Ceil => "Ceil", Round => "Round", Sign => "Sign", Clip => "Clip",
            Add => "Add", Sub => "Sub", Mul => "Mul", Div => "Div", Pow => "Pow",
            Min => "Min", Max => "Max", PRelu => "PRelu", And => "And", Or => "Or",
            Xor => "Xor", Not => "Not", Greater => "Greater", Less => "Less",
            Equal => "Equal", Where => "Where", Conv2d => "Conv", DepthwiseConv2d => "DepthwiseConv",
            Gemm => "Gemm", MatMul => "MatMul", BatchNorm => "BatchNormalization",
            LayerNorm => "LayerNormalization", InstanceNorm => "InstanceNormalization",
            MaxPool => "MaxPool", AveragePool => "AveragePool",
            GlobalAveragePool => "GlobalAveragePool", ReduceMean => "ReduceMean",
            ReduceSum => "ReduceSum", ReduceMax => "ReduceMax", Softmax => "Softmax",
            LogSoftmax => "LogSoftmax", Pad => "Pad", Concat => "Concat", Slice => "Slice",
            Transpose => "Transpose", Reshape => "Reshape", Flatten => "Flatten",
            Upsample => "Upsample", Identity => "Identity",
        }
    }
}

/// Operator attributes; unused fields keep their defaults.
#[derive(Debug, Clone, PartialEq)]
pub struct OpAttrs {
    /// Conv/pool kernel (kh, kw).
    pub kernel: (usize, usize),
    /// Conv/pool stride.
    pub stride: usize,
    /// Symmetric spatial padding.
    pub pad: usize,
    /// Conv output channels / Gemm output features.
    pub out_channels: usize,
    /// Conv groups (1 = dense, C = depthwise).
    pub groups: usize,
    /// Axis for Softmax / Reduce* / Concat / Flatten.
    pub axis: usize,
    /// Whether Reduce* keeps the reduced dim as 1.
    pub keepdims: bool,
    /// Upsample integer scale factor.
    pub scale: usize,
    /// Transpose permutation (empty = reverse dims).
    pub perm: Vec<usize>,
    /// Reshape target (must preserve numel).
    pub target_shape: Shape,
    /// Slice keeps `slice_frac` of the `axis` dim (numerator/denominator).
    pub slice_frac: (usize, usize),
}

impl Default for OpAttrs {
    fn default() -> Self {
        OpAttrs {
            kernel: (3, 3),
            stride: 1,
            pad: 1,
            out_channels: 16,
            groups: 1,
            axis: 1,
            keepdims: true,
            scale: 2,
            perm: vec![],
            target_shape: vec![],
            slice_frac: (1, 2),
        }
    }
}

/// An operator instance: kind + attributes.
#[derive(Debug, Clone, PartialEq)]
pub struct Op {
    pub kind: OpKind,
    pub attrs: OpAttrs,
}

impl Op {
    pub fn new(kind: OpKind) -> Self {
        Op { kind, attrs: OpAttrs::default() }
    }
    pub fn with_attrs(kind: OpKind, attrs: OpAttrs) -> Self {
        Op { kind, attrs }
    }

    /// Infer the output shape from operand shapes. Returns `None` when the
    /// operands are incompatible with this op (the generator uses this as
    /// its compatibility test).
    pub fn infer_shape(&self, inputs: &[&[usize]]) -> Option<Shape> {
        use OpKind::*;
        let a = self.attrs.clone();
        match self.kind.graph_arity() {
            n if n != inputs.len() => return None,
            _ => {}
        }
        let x = inputs[0];
        match self.kind {
            // unary elementwise + Not preserve shape
            Relu | LeakyRelu | Elu | Sigmoid | Tanh | Softplus | Gelu | HardSwish | Erf
            | Exp | Log | Sqrt | Reciprocal | Abs | Neg | Floor | Ceil | Round | Sign
            | Clip | Not | Identity => Some(x.to_vec()),
            Add | Sub | Mul | Div | Pow | Min | Max | PRelu | And | Or | Xor | Greater
            | Less | Equal => broadcast(x, inputs[1]),
            Where => {
                let ab = broadcast(x, inputs[1])?;
                broadcast(&ab, inputs[2])
            }
            Conv2d | DepthwiseConv2d => {
                // NCHW input
                if x.len() != 4 {
                    return None;
                }
                let (n, c, h, w) = (x[0], x[1], x[2], x[3]);
                let (kh, kw) = a.kernel;
                if h + 2 * a.pad < kh || w + 2 * a.pad < kw {
                    return None;
                }
                let oh = (h + 2 * a.pad - kh) / a.stride + 1;
                let ow = (w + 2 * a.pad - kw) / a.stride + 1;
                let oc = if self.kind == DepthwiseConv2d { c } else { a.out_channels };
                if oh == 0 || ow == 0 {
                    return None;
                }
                Some(vec![n, oc, oh, ow])
            }
            Gemm => {
                // [.., K] x implicit weight [K, out_channels]
                if x.is_empty() {
                    return None;
                }
                let mut out = x.to_vec();
                *out.last_mut().unwrap() = a.out_channels;
                Some(out)
            }
            MatMul => {
                let y = inputs[1];
                if x.len() < 2 || y.len() < 2 {
                    return None;
                }
                let (m, k1) = (x[x.len() - 2], x[x.len() - 1]);
                let (k2, nn) = (y[y.len() - 2], y[y.len() - 1]);
                if k1 != k2 || x[..x.len() - 2] != y[..y.len() - 2] {
                    return None;
                }
                let mut out = x[..x.len() - 2].to_vec();
                out.push(m);
                out.push(nn);
                Some(out)
            }
            BatchNorm | InstanceNorm => {
                if x.len() < 2 {
                    return None;
                }
                Some(x.to_vec())
            }
            LayerNorm => Some(x.to_vec()),
            MaxPool | AveragePool => {
                if x.len() != 4 {
                    return None;
                }
                let (n, c, h, w) = (x[0], x[1], x[2], x[3]);
                let (kh, kw) = a.kernel;
                if h + 2 * a.pad < kh || w + 2 * a.pad < kw {
                    return None;
                }
                let oh = (h + 2 * a.pad - kh) / a.stride + 1;
                let ow = (w + 2 * a.pad - kw) / a.stride + 1;
                if oh == 0 || ow == 0 {
                    return None;
                }
                Some(vec![n, c, oh, ow])
            }
            GlobalAveragePool => {
                if x.len() != 4 {
                    return None;
                }
                Some(vec![x[0], x[1], 1, 1])
            }
            ReduceMean | ReduceSum | ReduceMax => {
                if a.axis >= x.len() {
                    return None;
                }
                let mut out = x.to_vec();
                if a.keepdims {
                    out[a.axis] = 1;
                } else {
                    out.remove(a.axis);
                    if out.is_empty() {
                        out.push(1);
                    }
                }
                Some(out)
            }
            Softmax | LogSoftmax => {
                if a.axis >= x.len() {
                    return None;
                }
                Some(x.to_vec())
            }
            Pad => {
                let mut out = x.to_vec();
                let rank = out.len();
                // pad the trailing (spatial) dims
                for d in out.iter_mut().skip(rank.saturating_sub(2)) {
                    *d += 2 * a.pad;
                }
                Some(out)
            }
            Concat => {
                let y = inputs[1];
                if x.len() != y.len() || a.axis >= x.len() {
                    return None;
                }
                for d in 0..x.len() {
                    if d != a.axis && x[d] != y[d] {
                        return None;
                    }
                }
                let mut out = x.to_vec();
                out[a.axis] += y[a.axis];
                Some(out)
            }
            Slice => {
                if a.axis >= x.len() {
                    return None;
                }
                let (num, den) = a.slice_frac;
                let keep = (x[a.axis] * num / den).max(1);
                let mut out = x.to_vec();
                out[a.axis] = keep;
                Some(out)
            }
            Transpose => {
                let perm: Vec<usize> = if a.perm.is_empty() {
                    (0..x.len()).rev().collect()
                } else {
                    a.perm.clone()
                };
                if perm.len() != x.len() {
                    return None;
                }
                let mut seen = vec![false; x.len()];
                for &p in &perm {
                    if p >= x.len() || seen[p] {
                        return None;
                    }
                    seen[p] = true;
                }
                Some(perm.iter().map(|&p| x[p]).collect())
            }
            Reshape => {
                if a.target_shape.is_empty()
                    || a.target_shape.iter().product::<usize>() != x.iter().product::<usize>()
                {
                    return None;
                }
                Some(a.target_shape.clone())
            }
            Flatten => {
                if x.len() < 2 {
                    return None;
                }
                let ax = a.axis.min(x.len() - 1).max(1);
                let outer: usize = x[..ax].iter().product();
                let inner: usize = x[ax..].iter().product();
                Some(vec![outer, inner])
            }
            Upsample => {
                if x.len() != 4 {
                    return None;
                }
                Some(vec![x[0], x[1], x[2] * a.scale, x[3] * a.scale])
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn about_fifty_ops() {
        // paper: "We have identified about 50 such operators"
        assert!(OpKind::ALL.len() >= 50, "{} ops", OpKind::ALL.len());
        // ALL has no duplicates
        let mut v = OpKind::ALL.to_vec();
        v.sort();
        v.dedup();
        assert_eq!(v.len(), OpKind::ALL.len());
    }

    #[test]
    fn conv_shape() {
        let mut attrs = OpAttrs::default();
        attrs.kernel = (3, 3);
        attrs.stride = 2;
        attrs.pad = 1;
        attrs.out_channels = 32;
        let op = Op::with_attrs(OpKind::Conv2d, attrs);
        assert_eq!(op.infer_shape(&[&[1, 16, 28, 28]]), Some(vec![1, 32, 14, 14]));
        // wrong rank rejected
        assert_eq!(op.infer_shape(&[&[16, 28, 28]]), None);
    }

    #[test]
    fn depthwise_preserves_channels() {
        let op = Op::new(OpKind::DepthwiseConv2d);
        assert_eq!(op.infer_shape(&[&[1, 24, 16, 16]]), Some(vec![1, 24, 16, 16]));
    }

    #[test]
    fn matmul_shapes() {
        let op = Op::new(OpKind::MatMul);
        assert_eq!(op.infer_shape(&[&[4, 8][..], &[8, 3][..]]), Some(vec![4, 3]));
        assert_eq!(op.infer_shape(&[&[2, 4, 8][..], &[2, 8, 3][..]]), Some(vec![2, 4, 3]));
        assert_eq!(op.infer_shape(&[&[4, 8][..], &[7, 3][..]]), None);
    }

    #[test]
    fn gemm_replaces_last_dim() {
        let mut attrs = OpAttrs::default();
        attrs.out_channels = 10;
        let op = Op::with_attrs(OpKind::Gemm, attrs);
        assert_eq!(op.infer_shape(&[&[64, 512]]), Some(vec![64, 10]));
    }

    #[test]
    fn binary_broadcast() {
        let op = Op::new(OpKind::Add);
        assert_eq!(op.infer_shape(&[&[4, 1, 3][..], &[5, 3][..]]), Some(vec![4, 5, 3]));
        assert_eq!(op.infer_shape(&[&[2][..], &[3][..]]), None);
    }

    #[test]
    fn pool_and_global_pool() {
        let mut attrs = OpAttrs::default();
        attrs.kernel = (2, 2);
        attrs.stride = 2;
        attrs.pad = 0;
        let op = Op::with_attrs(OpKind::MaxPool, attrs);
        assert_eq!(op.infer_shape(&[&[1, 8, 32, 32]]), Some(vec![1, 8, 16, 16]));
        let gap = Op::new(OpKind::GlobalAveragePool);
        assert_eq!(gap.infer_shape(&[&[1, 8, 32, 32]]), Some(vec![1, 8, 1, 1]));
    }

    #[test]
    fn reduce_axis() {
        let mut attrs = OpAttrs::default();
        attrs.axis = 1;
        attrs.keepdims = false;
        let op = Op::with_attrs(OpKind::ReduceSum, attrs.clone());
        assert_eq!(op.infer_shape(&[&[2, 5, 7]]), Some(vec![2, 7]));
        attrs.keepdims = true;
        let op = Op::with_attrs(OpKind::ReduceSum, attrs);
        assert_eq!(op.infer_shape(&[&[2, 5, 7]]), Some(vec![2, 1, 7]));
    }

    #[test]
    fn transpose_perm_validation() {
        let mut attrs = OpAttrs::default();
        attrs.perm = vec![0, 2, 1];
        let op = Op::with_attrs(OpKind::Transpose, attrs);
        assert_eq!(op.infer_shape(&[&[2, 3, 4]]), Some(vec![2, 4, 3]));
        let mut bad = OpAttrs::default();
        bad.perm = vec![0, 0, 1];
        let op = Op::with_attrs(OpKind::Transpose, bad);
        assert_eq!(op.infer_shape(&[&[2, 3, 4]]), None);
    }

    #[test]
    fn reshape_must_preserve_numel() {
        let mut attrs = OpAttrs::default();
        attrs.target_shape = vec![6, 4];
        let op = Op::with_attrs(OpKind::Reshape, attrs);
        assert_eq!(op.infer_shape(&[&[2, 3, 4]]), Some(vec![6, 4]));
        let mut bad = OpAttrs::default();
        bad.target_shape = vec![5, 5];
        let op = Op::with_attrs(OpKind::Reshape, bad);
        assert_eq!(op.infer_shape(&[&[2, 3, 4]]), None);
    }

    #[test]
    fn concat_checks_other_dims() {
        let mut attrs = OpAttrs::default();
        attrs.axis = 1;
        let op = Op::with_attrs(OpKind::Concat, attrs);
        assert_eq!(op.infer_shape(&[&[2, 3, 4][..], &[2, 5, 4][..]]), Some(vec![2, 8, 4]));
        assert_eq!(op.infer_shape(&[&[2, 3, 4][..], &[2, 5, 9][..]]), None);
    }

    #[test]
    fn categories_cover_all_ops() {
        for &k in OpKind::ALL {
            let _ = k.category(); // no panic
            assert!(!k.name().is_empty());
            assert!(k.graph_arity() >= 1 && k.graph_arity() <= 3);
        }
    }
}
