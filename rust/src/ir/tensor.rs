//! Tensor shapes. All tensors are f32 (the paper targets f32 CPU pipelines);
//! rank is 1–4 with the ONNX NCHW convention for rank-4.

pub type Shape = Vec<usize>;

/// Number of elements.
pub fn numel(shape: &[usize]) -> usize {
    shape.iter().product()
}

/// Size in bytes (f32).
pub fn bytes(shape: &[usize]) -> usize {
    numel(shape) * 4
}

/// Numpy-style broadcast of two shapes (right-aligned).
pub fn broadcast(a: &[usize], b: &[usize]) -> Option<Shape> {
    let rank = a.len().max(b.len());
    let mut out = vec![0; rank];
    for i in 0..rank {
        let da = if i < rank - a.len() { 1 } else { a[i - (rank - a.len())] };
        let db = if i < rank - b.len() { 1 } else { b[i - (rank - b.len())] };
        out[i] = if da == db {
            da
        } else if da == 1 {
            db
        } else if db == 1 {
            da
        } else {
            return None;
        };
    }
    Some(out)
}

/// True when shapes are broadcast-compatible.
pub fn broadcastable(a: &[usize], b: &[usize]) -> bool {
    broadcast(a, b).is_some()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn numel_and_bytes() {
        assert_eq!(numel(&[2, 3, 4]), 24);
        assert_eq!(bytes(&[2, 3, 4]), 96);
        assert_eq!(numel(&[]), 1); // scalar
    }

    #[test]
    fn broadcast_rules() {
        assert_eq!(broadcast(&[4, 1, 3], &[5, 3]), Some(vec![4, 5, 3]));
        assert_eq!(broadcast(&[2, 3], &[2, 3]), Some(vec![2, 3]));
        assert_eq!(broadcast(&[3], &[2, 3]), Some(vec![2, 3]));
        assert_eq!(broadcast(&[2, 3], &[3, 2]), None);
    }
}
