//! Pipeline intermediate representation.
//!
//! A [`Pipeline`](pipeline::Pipeline) is a DAG of [`Stage`](pipeline::Stage)s
//! — the analogue of Halide `Func`s. Each stage applies one tensor
//! [`Op`](op::Op) to the outputs of earlier stages (or pipeline inputs) and
//! has a statically inferred output shape. The random generator
//! ([`crate::onnx_gen`]) builds ONNX-style graphs directly in this IR; the
//! lowering pass ([`crate::lower`]) turns each stage into a loop nest.

pub mod tensor;
pub mod op;
pub mod pipeline;

pub use op::{Op, OpAttrs, OpCategory, OpKind};
pub use pipeline::{Pipeline, SourceRef, Stage};
pub use tensor::Shape;
