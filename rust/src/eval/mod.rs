//! Evaluation harnesses for the paper's figures, plus the perf benches.
//!
//! * [`metrics`] — Fig 8: average error %, maximum error %, R².
//! * [`ranking`] — Fig 9: pairwise schedule ranking accuracy.
//! * [`perf`] — dense-vs-sparse engine benchmarks (`BENCH_3.json`).
//! * [`serve_bench`] — naive-vs-coalesced serving benchmark
//!   (`BENCH_4.json`).
//! * [`engine_bench`] — native-engine micro-benchmarks against the
//!   frozen PR-4 compute core (`BENCH_5.json`), with the baseline kept
//!   in `legacy_engine`.
//! * [`simd_bench`] — scalar vs SIMD vs int8 inference lanes of the
//!   native engine, with the numeric-mode gates (`BENCH_8.json`).
//! * [`net_bench`] — the TCP front-end under the loadgen client fleet,
//!   with bitwise verification (`BENCH_6.json`).
//! * [`autotune_bench`] — concurrent-fleet vs sequential autotuning
//!   through one shared service, cross-checked bitwise
//!   (`BENCH_7.json`).
//! * [`analysis_bench`] — per-call vs precomputed-analysis schedule
//!   validation throughput, verdict-checked (`BENCH_9.json`).
//! * [`scale_bench`] — out-of-core scaling tiers: in-RAM vs streamed
//!   training and full-graph vs partitioned steps, bitwise-checked
//!   (`BENCH_10.json`).

pub mod metrics;
pub mod ranking;
pub mod harness;
pub mod perf;
pub mod serve_bench;
pub mod engine_bench;
pub mod simd_bench;
pub mod net_bench;
pub mod autotune_bench;
pub mod analysis_bench;
pub mod scale_bench;
pub(crate) mod legacy_engine;

pub use metrics::{regression_metrics, RegressionMetrics};
pub use ranking::{pairwise_ranking_accuracy, rank_networks, RankResult};
