//! Evaluation harnesses for the paper's figures.
//!
//! * [`metrics`] — Fig 8: average error %, maximum error %, R².
//! * [`ranking`] — Fig 9: pairwise schedule ranking accuracy.

pub mod metrics;
pub mod ranking;
pub mod harness;

pub use metrics::{regression_metrics, RegressionMetrics};
pub use ranking::{pairwise_ranking_accuracy, rank_networks, RankResult};
