//! Evaluation harnesses for the paper's figures, plus the perf bench.
//!
//! * [`metrics`] — Fig 8: average error %, maximum error %, R².
//! * [`ranking`] — Fig 9: pairwise schedule ranking accuracy.
//! * [`perf`] — dense-vs-sparse engine benchmarks (`BENCH_3.json`).
//! * [`serve_bench`] — naive-vs-coalesced serving benchmark
//!   (`BENCH_4.json`).

pub mod metrics;
pub mod ranking;
pub mod harness;
pub mod perf;
pub mod serve_bench;

pub use metrics::{regression_metrics, RegressionMetrics};
pub use ranking::{pairwise_ranking_accuracy, rank_networks, RankResult};
