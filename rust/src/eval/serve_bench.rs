//! Serving-path benchmark — `BENCH_4.json`.
//!
//! Measures the redesign this PR exists for: N concurrent clients each
//! scoring a candidate list of mixed-size graphs,
//!
//! * **naive** — every client calls `Predictor::predict` once *per
//!   candidate* (the pre-service anti-pattern: tiny single-graph batches,
//!   the packed engine never sees a real batch), vs
//! * **coalesced** — every client submits its whole candidate list as one
//!   [`PredictRequest`] to a shared [`PredictService`], whose coalescer
//!   fuses concurrent requests into block-diagonal packed batches.
//!
//! Both sides compute identical predictions (verified bitwise inside the
//! run — coalescing must not change results, only throughput). CI runs
//! the `--fast` variant via `gcn-perf bench --fast --require-speedup`,
//! which asserts the coalesced path beats the naive one.

use crate::dataset::builder::{build_dataset, sample_from_schedule, DataGenConfig};
use crate::dataset::sample::GraphSample;
use crate::lower::lower_pipeline;
use crate::predictor::{GcnPredictor, PredictRequest, PredictService, Predictor, ServiceConfig};
use crate::runtime::{Backend, NativeBackend};
use crate::schedule::random::random_pipeline_schedule;
use crate::sim::Machine;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{anyhow, ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

#[derive(Debug, Clone)]
pub struct ServeBenchConfig {
    /// Short run (CI smoke).
    pub fast: bool,
    pub seed: u64,
}

impl Default for ServeBenchConfig {
    fn default() -> Self {
        ServeBenchConfig { fast: false, seed: 3 }
    }
}

/// The measured comparison (means over the measured rounds).
#[derive(Debug, Clone)]
pub struct ServeBenchReport {
    pub fast: bool,
    pub clients: usize,
    pub candidates_per_client: usize,
    pub rounds: usize,
    pub naive_mean_ns: f64,
    pub coalesced_mean_ns: f64,
    pub naive_graphs_per_s: f64,
    pub coalesced_graphs_per_s: f64,
    /// Fused `predict` calls the service needed for the measured rounds.
    pub coalesced_batches: usize,
    /// naive wall time / coalesced wall time (> 1 means the service wins).
    pub speedup: f64,
}

impl ServeBenchReport {
    /// Error unless coalesced serving beat naive per-candidate calls.
    /// Enforced by the serial CI bench step (`bench --require-speedup`),
    /// not by `cargo test`, so the test suite stays deterministic on
    /// noisy shared runners.
    pub fn require_speedup(&self) -> Result<()> {
        ensure!(
            self.speedup > 1.0,
            "coalesced serving did not beat naive per-candidate calls: {:.3}x (expected > 1.0)",
            self.speedup
        );
        Ok(())
    }
}

/// Per-client candidate lists with mixed graph sizes: generator pipelines
/// (~5–10 stages) interleaved with >48-stage resnet50 schedules.
fn build_worklists(
    cfg: &ServeBenchConfig,
    clients: usize,
    per_client: usize,
) -> Result<(Arc<dyn Predictor>, Vec<Vec<GraphSample>>)> {
    let ds = build_dataset(&DataGenConfig {
        n_pipelines: 8,
        schedules_per_pipeline: 4,
        seed: cfg.seed,
        ..Default::default()
    });
    let stats = ds.stats.clone().context("dataset stats")?;

    let net = crate::zoo::resnet50();
    let nests = lower_pipeline(&net);
    let machine = Machine::default();
    let mut rng = Rng::new(cfg.seed ^ 0x5EB);
    let large: Vec<GraphSample> = (0..4u32)
        .map(|sid| {
            let sched = random_pipeline_schedule(&net, &nests, &mut rng);
            sample_from_schedule(&net, &nests, &sched, &machine, 1000, sid, &mut rng)
        })
        .collect();

    let mut lists = Vec::with_capacity(clients);
    for c in 0..clients {
        let mut list = Vec::with_capacity(per_client);
        for i in 0..per_client {
            if i % 4 == 3 {
                list.push(large[(c + i) % large.len()].clone());
            } else {
                list.push(ds.samples[(c * per_client + i) % ds.samples.len()].clone());
            }
        }
        lists.push(list);
    }

    let backend = NativeBackend::new();
    let params = backend.init_params(cfg.seed);
    let predictor: Arc<dyn Predictor> =
        Arc::new(GcnPredictor::new(Box::new(backend), params, stats));
    Ok((predictor, lists))
}

/// One naive round: each client thread scores its candidates one call per
/// sample, directly against the shared predictor.
fn naive_round(
    predictor: &Arc<dyn Predictor>,
    lists: &[Vec<GraphSample>],
) -> Result<(Duration, Vec<Vec<f64>>)> {
    let t0 = Instant::now();
    let outs: Vec<Result<Vec<f64>>> = std::thread::scope(|scope| {
        let handles: Vec<_> = lists
            .iter()
            .map(|list| {
                let p = Arc::clone(predictor);
                scope.spawn(move || -> Result<Vec<f64>> {
                    let mut out = Vec::with_capacity(list.len());
                    for s in list {
                        let v = p.predict(&[s])?;
                        out.push(*v.first().ok_or_else(|| anyhow!("empty prediction"))?);
                    }
                    Ok(out)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow!("naive client panicked")).and_then(|r| r))
            .collect()
    });
    let dt = t0.elapsed();
    let outs: Result<Vec<Vec<f64>>> = outs.into_iter().collect();
    Ok((dt, outs?))
}

/// One coalesced round: each client thread submits its whole candidate
/// list as one request to the shared service.
fn coalesced_round(
    service: &PredictService,
    lists: &[Vec<GraphSample>],
) -> Result<(Duration, Vec<Vec<f64>>)> {
    let t0 = Instant::now();
    let outs: Vec<Result<Vec<f64>>> = std::thread::scope(|scope| {
        let handles: Vec<_> = lists
            .iter()
            .map(|list| {
                scope.spawn(move || -> Result<Vec<f64>> {
                    Ok(service.predict_blocking(PredictRequest::new(list.clone()))?.predictions)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().map_err(|_| anyhow!("service client panicked")).and_then(|r| r))
            .collect()
    });
    let dt = t0.elapsed();
    let outs: Result<Vec<Vec<f64>>> = outs.into_iter().collect();
    Ok((dt, outs?))
}

/// Run the naive-vs-coalesced comparison. Results of the two paths are
/// checked bitwise-equal before any timing is trusted.
pub fn run_serve_bench(cfg: &ServeBenchConfig) -> Result<ServeBenchReport> {
    let (clients, per_client, rounds) = if cfg.fast { (4, 24, 2) } else { (8, 64, 4) };
    let (predictor, lists) = build_worklists(cfg, clients, per_client)?;
    let service = PredictService::spawn(
        Arc::clone(&predictor),
        ServiceConfig { queue_cap: clients.max(4), ..Default::default() },
    );

    // warmup + correctness: coalescing must not change a single bit
    let (_, naive_preds) = naive_round(&predictor, &lists)?;
    let (_, coalesced_preds) = coalesced_round(&service, &lists)?;
    ensure!(
        naive_preds == coalesced_preds,
        "coalesced predictions diverge from direct per-candidate predictions"
    );

    let batches_before = service.stats().batches;
    let mut naive_ns = 0.0;
    let mut coalesced_ns = 0.0;
    for _ in 0..rounds {
        let (dn, _) = naive_round(&predictor, &lists)?;
        let (dc, _) = coalesced_round(&service, &lists)?;
        naive_ns += dn.as_nanos() as f64;
        coalesced_ns += dc.as_nanos() as f64;
    }
    let coalesced_batches = service.stats().batches - batches_before;
    let naive_mean_ns = naive_ns / rounds as f64;
    let coalesced_mean_ns = coalesced_ns / rounds as f64;
    let total = (clients * per_client) as f64;
    Ok(ServeBenchReport {
        fast: cfg.fast,
        clients,
        candidates_per_client: per_client,
        rounds,
        naive_mean_ns,
        coalesced_mean_ns,
        naive_graphs_per_s: total / (naive_mean_ns / 1e9),
        coalesced_graphs_per_s: total / (coalesced_mean_ns / 1e9),
        coalesced_batches,
        speedup: naive_mean_ns / coalesced_mean_ns,
    })
}

/// Serialize a report to `BENCH_4.json`.
pub fn write_serve_report(report: &ServeBenchReport, path: &Path) -> Result<()> {
    let j = Json::obj(vec![
        ("bench", Json::Str("serving: per-candidate calls vs coalesced service".into())),
        ("fast", Json::Num(if report.fast { 1.0 } else { 0.0 })),
        ("clients", Json::Num(report.clients as f64)),
        ("candidates_per_client", Json::Num(report.candidates_per_client as f64)),
        ("rounds", Json::Num(report.rounds as f64)),
        (
            "naive",
            Json::obj(vec![
                ("mean_ns", Json::Num(report.naive_mean_ns)),
                ("graphs_per_s", Json::Num(report.naive_graphs_per_s)),
            ]),
        ),
        (
            "coalesced",
            Json::obj(vec![
                ("mean_ns", Json::Num(report.coalesced_mean_ns)),
                ("graphs_per_s", Json::Num(report.coalesced_graphs_per_s)),
                ("fused_batches", Json::Num(report.coalesced_batches as f64)),
            ]),
        ),
        ("speedup_naive_over_coalesced", Json::Num(report.speedup)),
    ]);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, j.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_serve_bench_runs_and_reports() {
        // Structure + the built-in bitwise equality check only. The
        // wall-clock acceptance bar (coalesced beats naive) is enforced by
        // the serial CI step `gcn-perf bench --fast --require-speedup`,
        // not here — `cargo test` shares cores with sibling tests.
        let report = run_serve_bench(&ServeBenchConfig { fast: true, seed: 7 }).unwrap();
        assert_eq!(report.clients, 4);
        assert!(report.naive_mean_ns > 0.0 && report.coalesced_mean_ns > 0.0);
        assert!(report.speedup.is_finite() && report.speedup > 0.0);
        assert!(report.coalesced_batches > 0);
        eprintln!("serving speedup (naive/coalesced): {:.2}x", report.speedup);

        let path = std::env::temp_dir().join("gcn_perf_bench4_test.json");
        write_serve_report(&report, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("speedup_naive_over_coalesced"));
        crate::util::json::Json::parse(&text).unwrap();
        std::fs::remove_file(&path).ok();
    }
}
