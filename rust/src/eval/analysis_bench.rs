//! Candidate-validation throughput benchmark — `BENCH_9.json`.
//!
//! Measures the analyzer's search-side pruning path: validating one
//! candidate schedule
//!
//! * **per-call** — `legality::check_pipeline(&p, &nests, &sched)`, which
//!   rebuilds the per-pipeline tables (consumer lists, spatial extents)
//!   on every call — what a caller without precomputation pays, and what
//!   the strategies paid before this PR, vs
//! * **precomputed** — one [`AnalyzedPipeline::build`] up front (its cost
//!   is *included* in the timed region), then
//!   [`AnalyzedPipeline::check_schedule`] table lookups per candidate —
//!   the path [`crate::autotune::BeamStrategy`] and
//!   [`crate::autotune::EvolutionStrategy`] now use.
//!
//! Both paths classify an identical mixed legal/illegal schedule corpus;
//! the run refuses to report timings unless the accept/reject verdicts
//! match schedule-for-schedule. CI runs the `--fast` variant via
//! `gcn-perf bench --fast --require-speedup`.

use crate::analysis::AnalyzedPipeline;
use crate::lower::lower_pipeline;
use crate::schedule::legality::check_pipeline;
use crate::schedule::primitives::{ComputeLoc, PipelineSchedule};
use crate::schedule::random::random_pipeline_schedule;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{ensure, Result};
use std::path::Path;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct AnalysisBenchConfig {
    /// Short run (CI smoke).
    pub fast: bool,
    pub seed: u64,
}

impl Default for AnalysisBenchConfig {
    fn default() -> Self {
        AnalysisBenchConfig { fast: false, seed: 5 }
    }
}

/// The measured comparison (totals over all rounds).
#[derive(Debug, Clone)]
pub struct AnalysisBenchReport {
    pub fast: bool,
    pub network: String,
    pub n_schedules: usize,
    /// How many of the corpus schedules are illegal (mutated).
    pub n_illegal: usize,
    pub rounds: usize,
    pub per_call_mean_ns: f64,
    pub precomputed_mean_ns: f64,
    pub per_call_checks_per_s: f64,
    pub precomputed_checks_per_s: f64,
    /// per-call wall time / precomputed wall time (> 1 = tables win).
    pub speedup: f64,
}

impl AnalysisBenchReport {
    /// Error unless the precomputed path beat per-call validation.
    /// Enforced by the serial CI bench step (`bench --require-speedup`),
    /// not by `cargo test`, so the test suite stays deterministic on
    /// noisy shared runners.
    pub fn require_speedup(&self) -> Result<()> {
        ensure!(
            self.speedup > 1.0,
            "precomputed analysis did not beat per-call validation: {:.3}x (expected > 1.0)",
            self.speedup
        );
        Ok(())
    }
}

/// Corrupt one stage of a legal schedule into a rotating `S0xx` violation
/// class, so the corpus exercises every rejection path.
fn corrupt(sched: &mut PipelineSchedule, class: usize, rng: &mut Rng) {
    let sid = rng.gen_range(sched.stages.len());
    let s = &mut sched.stages[sid];
    match class % 5 {
        0 => s.vector_width = 3,
        1 => s.unroll = 5,
        2 => s.parallel_depth = 9,
        3 => s.order = vec![0; s.order.len()],
        _ => s.compute = ComputeLoc::At { consumer: sid, level: 2 },
    }
}

/// Run the per-call vs precomputed comparison over a mixed corpus of
/// schedules for one zoo network.
pub fn run_analysis_bench(cfg: &AnalysisBenchConfig) -> Result<AnalysisBenchReport> {
    let (n_schedules, rounds) = if cfg.fast { (400, 2) } else { (4000, 4) };
    let p = crate::zoo::unet();
    let nests = lower_pipeline(&p);
    let mut rng = Rng::new(cfg.seed);

    let mut corpus: Vec<PipelineSchedule> = Vec::with_capacity(n_schedules);
    let mut n_illegal = 0;
    for i in 0..n_schedules {
        let mut sched = random_pipeline_schedule(&p, &nests, &mut rng);
        if i % 2 == 1 {
            corrupt(&mut sched, i / 2, &mut rng);
            n_illegal += 1;
        }
        corpus.push(sched);
    }

    // correctness first: the two paths must agree schedule-for-schedule
    let ap = AnalyzedPipeline::build(&p, &nests);
    let verdicts_per_call: Vec<bool> =
        corpus.iter().map(|s| check_pipeline(&p, &nests, s).is_ok()).collect();
    let verdicts_precomputed: Vec<bool> =
        corpus.iter().map(|s| ap.check_schedule(s).is_ok()).collect();
    ensure!(
        verdicts_per_call == verdicts_precomputed,
        "per-call and precomputed legality verdicts diverge"
    );
    // the corrupted half must actually be rejected, or the bench measures
    // nothing but the accept fast path
    ensure!(
        verdicts_per_call.iter().filter(|ok| !**ok).count() >= n_illegal / 2,
        "corruption failed to produce a meaningfully illegal corpus"
    );

    let mut per_call_ns = 0.0;
    let mut precomputed_ns = 0.0;
    let mut sink = 0usize;
    for _ in 0..rounds {
        let t0 = Instant::now();
        for s in &corpus {
            if check_pipeline(&p, &nests, s).is_ok() {
                sink += 1;
            }
        }
        per_call_ns += t0.elapsed().as_nanos() as f64;

        // the precomputed side pays its one-time build inside the timing
        let t0 = Instant::now();
        let ap = AnalyzedPipeline::build(&p, &nests);
        for s in &corpus {
            if ap.check_schedule(s).is_ok() {
                sink += 1;
            }
        }
        precomputed_ns += t0.elapsed().as_nanos() as f64;
    }
    ensure!(sink > 0, "benchmark corpus was entirely illegal");

    let per_call_mean_ns = per_call_ns / rounds as f64;
    let precomputed_mean_ns = precomputed_ns / rounds as f64;
    let total = n_schedules as f64;
    Ok(AnalysisBenchReport {
        fast: cfg.fast,
        network: p.name.clone(),
        n_schedules,
        n_illegal,
        rounds,
        per_call_mean_ns,
        precomputed_mean_ns,
        per_call_checks_per_s: total / (per_call_mean_ns / 1e9),
        precomputed_checks_per_s: total / (precomputed_mean_ns / 1e9),
        speedup: per_call_mean_ns / precomputed_mean_ns,
    })
}

/// Serialize a report to `BENCH_9.json`.
pub fn write_analysis_report(report: &AnalysisBenchReport, path: &Path) -> Result<()> {
    let j = Json::obj(vec![
        ("bench", Json::Str("schedule validation: per-call vs precomputed analysis".into())),
        ("fast", Json::Num(if report.fast { 1.0 } else { 0.0 })),
        ("network", Json::Str(report.network.clone())),
        ("n_schedules", Json::Num(report.n_schedules as f64)),
        ("n_illegal", Json::Num(report.n_illegal as f64)),
        ("rounds", Json::Num(report.rounds as f64)),
        (
            "per_call",
            Json::obj(vec![
                ("mean_ns", Json::Num(report.per_call_mean_ns)),
                ("checks_per_s", Json::Num(report.per_call_checks_per_s)),
            ]),
        ),
        (
            "precomputed",
            Json::obj(vec![
                ("mean_ns", Json::Num(report.precomputed_mean_ns)),
                ("checks_per_s", Json::Num(report.precomputed_checks_per_s)),
            ]),
        ),
        ("speedup_per_call_over_precomputed", Json::Num(report.speedup)),
    ]);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, j.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_analysis_bench_runs_and_reports() {
        // Structure + the built-in verdict-equality check only; the
        // wall-clock bar (precomputed beats per-call) is enforced by the
        // serial CI step `gcn-perf bench --fast --require-speedup`.
        let report = run_analysis_bench(&AnalysisBenchConfig { fast: true, seed: 7 }).unwrap();
        assert_eq!(report.n_schedules, 400);
        assert!(report.n_illegal > 0);
        assert!(report.per_call_mean_ns > 0.0 && report.precomputed_mean_ns > 0.0);
        assert!(report.speedup.is_finite() && report.speedup > 0.0);
        eprintln!("validation speedup (per-call/precomputed): {:.2}x", report.speedup);

        let path = std::env::temp_dir().join("gcn_perf_bench9_test.json");
        write_analysis_report(&report, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("speedup_per_call_over_precomputed"));
        crate::util::json::Json::parse(&text).unwrap();
        std::fs::remove_file(&path).ok();
    }
}
