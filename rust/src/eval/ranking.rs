//! Fig 9: pairwise schedule ranking on real-world networks.
//!
//! "For all possible pair-wise combinations of schedules belonging to a
//! network, we count the number of pairs in which the model assigned a
//! lower run time to the faster schedule."

#[derive(Debug, Clone)]
pub struct RankResult {
    pub network: String,
    pub n_schedules: usize,
    pub n_pairs: usize,
    pub correct_pairs: usize,
}

impl RankResult {
    pub fn accuracy_pct(&self) -> f64 {
        if self.n_pairs == 0 {
            return 0.0;
        }
        100.0 * self.correct_pairs as f64 / self.n_pairs as f64
    }

    pub fn row(&self) -> String {
        format!(
            "{:<14} {:>10} {:>10} {:>10.1}%",
            self.network,
            self.n_schedules,
            self.n_pairs,
            self.accuracy_pct()
        )
    }

    pub fn header() -> String {
        format!("{:<14} {:>10} {:>10} {:>11}", "network", "schedules", "pairs", "ranked ok")
    }
}

/// Pairwise ranking accuracy of predictions vs ground truth. Pairs whose
/// true runtimes are within `tie_eps` relative are skipped (measurement
/// noise makes their order meaningless).
pub fn pairwise_ranking_accuracy(
    network: &str,
    y_true: &[f64],
    y_pred: &[f64],
    tie_eps: f64,
) -> RankResult {
    assert_eq!(y_true.len(), y_pred.len());
    let mut n_pairs = 0;
    let mut correct = 0;
    for i in 0..y_true.len() {
        for j in (i + 1)..y_true.len() {
            let rel = (y_true[i] - y_true[j]).abs() / y_true[i].max(y_true[j]).max(1e-12);
            if rel < tie_eps {
                continue;
            }
            n_pairs += 1;
            let true_i_faster = y_true[i] < y_true[j];
            let pred_i_faster = y_pred[i] < y_pred[j];
            if true_i_faster == pred_i_faster {
                correct += 1;
            }
        }
    }
    RankResult {
        network: network.to_string(),
        n_schedules: y_true.len(),
        n_pairs,
        correct_pairs: correct,
    }
}

/// Rank a batch of networks and append the average row (Fig 9's ~75%).
pub fn rank_networks(results: Vec<RankResult>) -> (Vec<RankResult>, f64) {
    let avg = if results.is_empty() {
        0.0
    } else {
        results.iter().map(|r| r.accuracy_pct()).sum::<f64>() / results.len() as f64
    };
    (results, avg)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_ranking() {
        let t = [1.0, 2.0, 3.0, 4.0];
        let p = [0.1, 0.2, 0.3, 0.4]; // scale-free: order is what counts
        let r = pairwise_ranking_accuracy("net", &t, &p, 0.0);
        assert_eq!(r.n_pairs, 6);
        assert_eq!(r.correct_pairs, 6);
        assert_eq!(r.accuracy_pct(), 100.0);
    }

    #[test]
    fn inverted_ranking() {
        let t = [1.0, 2.0, 3.0];
        let p = [3.0, 2.0, 1.0];
        let r = pairwise_ranking_accuracy("net", &t, &p, 0.0);
        assert_eq!(r.correct_pairs, 0);
    }

    #[test]
    fn ties_skipped() {
        let t = [1.0, 1.0001, 5.0];
        let p = [1.0, 0.9, 10.0];
        let r = pairwise_ranking_accuracy("net", &t, &p, 0.01);
        assert_eq!(r.n_pairs, 2); // the near-tie pair dropped
        assert_eq!(r.correct_pairs, 2);
    }

    #[test]
    fn average_across_networks() {
        let a = pairwise_ranking_accuracy("a", &[1.0, 2.0], &[1.0, 2.0], 0.0);
        let b = pairwise_ranking_accuracy("b", &[1.0, 2.0], &[2.0, 1.0], 0.0);
        let (_, avg) = rank_networks(vec![a, b]);
        assert!((avg - 50.0).abs() < 1e-9);
    }
}
