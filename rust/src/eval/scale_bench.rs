//! Out-of-core scaling benchmark — `BENCH_10.json`.
//!
//! For each scale tier (1k/10k/100k stages; `--fast` trims to 1k/10k
//! under a tightened node budget) the bench builds one synthetic
//! [`crate::zoo::large`] corpus and trains one epoch through two
//! storage paths and two batching paths:
//!
//! * **in-RAM vs streamed** — [`crate::train::train`] over the resident
//!   [`crate::dataset::sample::Dataset`] (plus its split copies) vs
//!   [`crate::train::train_source`] over the [`ShardedDataset`] written
//!   by [`ShardWriter`], with the resident corpus dropped first. The two
//!   runs must agree bitwise (same loop, same split, same shuffles —
//!   checked before any number is reported); the streamed lane's peak
//!   [`live_bytes`] window is the memory-ceiling claim.
//! * **full-graph vs partitioned** — on tiers whose graphs exceed the
//!   node budget, one training step over the whole packed graph vs the
//!   block-aligned partition steps ([`crate::model::partition`]), each
//!   peak-windowed separately so the comparison is workspace-only.
//!
//! Latency and resident-memory summaries go through [`Quantiles`]
//! (p50/p90/max per predict chunk). CI runs the serial step
//! `gcn-perf bench --fast --require-speedup`, which asserts the
//! streamed lane beat the in-RAM peak *and* stayed under one corpus
//! copy, and that partitioned steps fit where full-graph steps did not;
//! `cargo test` only checks structure (parallel sibling tests pollute
//! the process-wide peak window).

use crate::constants::LEARNING_RATE;
use crate::dataset::sample::GraphSample;
use crate::dataset::shard::{ShardWriter, ShardedDataset};
use crate::dataset::stream::{split_source, SourceView};
use crate::model::partition::{combine_runtimes, partition_sample};
use crate::model::PackedBatch;
use crate::predictor::{GcnView, Predictor};
use crate::runtime::{Backend, NativeBackend, Params};
use crate::train::{train, train_source, TrainConfig};
use crate::util::alloc_count::{live_bytes, peak_bytes, reset_peak_bytes};
use crate::util::json::Json;
use crate::util::stats::Quantiles;
use crate::zoo::large::{build_large_dataset, LargeConfig, LargeStyle};
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::time::Instant;

#[derive(Debug, Clone)]
pub struct ScaleBenchConfig {
    /// Short run (CI smoke): 1k/10k tiers instead of 1k/10k/100k, and
    /// the node budget tightened to ≤ 2048 so the 10k tier still trains
    /// through several partitions per graph.
    pub fast: bool,
    pub seed: u64,
    /// Per-batch packed-node ceiling for every lane (train, step probes,
    /// predict). Defaults to [`crate::constants::node_budget`].
    pub node_budget: usize,
}

impl Default for ScaleBenchConfig {
    fn default() -> Self {
        ScaleBenchConfig { fast: false, seed: 11, node_budget: crate::constants::node_budget() }
    }
}

/// One scale tier's measured lanes.
#[derive(Debug, Clone)]
pub struct TierReport {
    pub n_stages: usize,
    pub n_samples: usize,
    /// Feature + edge + runtime payload bytes of one corpus copy.
    pub corpus_bytes: u64,
    pub in_ram_train_s: f64,
    /// Peak heap over the in-RAM lane, measured from the pre-corpus
    /// baseline — includes the resident dataset and its split copies.
    pub in_ram_peak_bytes: u64,
    pub streamed_train_s: f64,
    /// Peak heap over the streamed lane from the same baseline — the
    /// corpus lives on disk, so this is index + one decoded batch.
    pub streamed_peak_bytes: u64,
    pub streamed_nodes_per_s: f64,
    /// Whether this tier's graphs exceed the node budget (step-probe
    /// lanes below only run when they do).
    pub partitioned: bool,
    pub full_step_s: f64,
    pub full_step_peak_bytes: u64,
    pub part_step_s: f64,
    pub part_step_peak_bytes: u64,
    /// Fraction of the probe graph's edges dropped at partition cuts
    /// (0.0 when the tier fits the budget whole) — the size of the
    /// pinned approximation, recorded so regressions are visible.
    pub cut_edge_fraction: f64,
    pub predict_chunk_ms_p50: f64,
    pub predict_chunk_ms_p90: f64,
    pub predict_chunk_ms_max: f64,
    pub predict_live_bytes_p50: f64,
    pub predict_live_bytes_max: f64,
}

#[derive(Debug, Clone)]
pub struct ScaleBenchReport {
    pub fast: bool,
    /// Effective node budget the lanes ran under.
    pub node_budget: usize,
    pub style: String,
    pub tiers: Vec<TierReport>,
}

fn mib(bytes: u64) -> f64 {
    bytes as f64 / (1024.0 * 1024.0)
}

impl ScaleBenchReport {
    /// Error unless the out-of-core paths actually won. Enforced by the
    /// serial CI bench step (`bench --require-speedup`), not by
    /// `cargo test`, so the test suite stays deterministic on noisy
    /// shared runners.
    pub fn require_speedup(&self) -> Result<()> {
        ensure!(!self.tiers.is_empty(), "scale bench produced no tiers");
        ensure!(
            self.tiers.iter().any(|t| t.partitioned),
            "no tier exceeded the node budget ({}) — the partition path went unexercised",
            self.node_budget
        );
        let top = self.tiers.last().unwrap();
        ensure!(
            top.streamed_peak_bytes < top.in_ram_peak_bytes,
            "streamed training did not beat the in-RAM peak at the {}-stage tier: \
             {:.1} MiB vs {:.1} MiB",
            top.n_stages,
            mib(top.streamed_peak_bytes),
            mib(top.in_ram_peak_bytes)
        );
        ensure!(
            top.streamed_peak_bytes < top.corpus_bytes,
            "streamed peak ({:.1} MiB) exceeded one corpus copy ({:.1} MiB) at the \
             {}-stage tier — the memory ceiling does not hold",
            mib(top.streamed_peak_bytes),
            mib(top.corpus_bytes),
            top.n_stages
        );
        for t in &self.tiers {
            if t.partitioned {
                ensure!(
                    t.part_step_peak_bytes < t.full_step_peak_bytes,
                    "partitioned steps did not fit under the full-graph step at the \
                     {}-stage tier: {:.1} MiB vs {:.1} MiB",
                    t.n_stages,
                    mib(t.part_step_peak_bytes),
                    mib(t.full_step_peak_bytes)
                );
            }
        }
        Ok(())
    }
}

/// On-disk payload bytes of one sample (header + edges + features +
/// measurements) — the same accounting the shard writer uses.
fn sample_bytes(s: &GraphSample) -> u64 {
    (16 + std::mem::size_of_val(s.edges.as_slice())
        + std::mem::size_of_val(s.inv.as_slice())
        + std::mem::size_of_val(s.dep.as_slice())
        + std::mem::size_of_val(&s.runs)) as u64
}

/// `(n_stages, n_pipelines, schedules_per_pipeline)` per tier.
fn tier_spec(fast: bool) -> Vec<(usize, u32, u32)> {
    if fast {
        vec![(1_000, 2, 4), (10_000, 2, 3)]
    } else {
        vec![(1_000, 2, 8), (10_000, 2, 4), (100_000, 2, 2)]
    }
}

/// Stream the whole corpus through the predictor in node-budget chunks,
/// summarizing per-chunk latency and resident memory with [`Quantiles`].
struct PredictLane {
    chunk_ms: Quantiles,
    live: Quantiles,
}

fn predict_lane(
    rt: &dyn Backend,
    params: &Params,
    sd: &ShardedDataset,
    node_budget: usize,
) -> Result<PredictLane> {
    let stats = sd.stats().context("corpus stats missing from the shard index")?.clone();
    let view = SourceView::whole(sd, stats);
    let p = GcnView { backend: rt, params, stats: &view.stats };
    let mut chunk_ms = Vec::new();
    let mut live = Vec::new();
    for chunk in view.iter().budget_chunks(node_budget) {
        let chunk = chunk?;
        let t0 = Instant::now();
        let preds = if chunk.len() == 1 && chunk[0].n_stages as usize > node_budget {
            let part = partition_sample(&chunk[0], node_budget);
            let refs: Vec<&GraphSample> = part.parts.iter().collect();
            vec![combine_runtimes(&p.predict(&refs)?)]
        } else {
            let refs: Vec<&GraphSample> = chunk.iter().collect();
            p.predict(&refs)?
        };
        ensure!(
            preds.iter().all(|y| y.is_finite()),
            "non-finite prediction while streaming the scale-bench corpus"
        );
        chunk_ms.push(t0.elapsed().as_secs_f64() * 1e3);
        live.push(live_bytes() as f64);
    }
    Ok(PredictLane { chunk_ms: Quantiles::new(&chunk_ms), live: Quantiles::new(&live) })
}

fn run_tier(
    rt: &dyn Backend,
    cfg: &ScaleBenchConfig,
    n_stages: usize,
    n_pipelines: u32,
    scheds: u32,
    node_budget: usize,
) -> Result<TierReport> {
    let lcfg = LargeConfig {
        style: LargeStyle::Transformer,
        n_stages,
        n_pipelines,
        schedules_per_pipeline: scheds,
        seed: cfg.seed,
    };
    let dir = std::env::temp_dir().join(format!("gcn_perf_scale_{n_stages}x{n_pipelines}x{scheds}"));
    std::fs::remove_dir_all(&dir).ok();

    // every lane's peak is measured from this pre-corpus baseline, so
    // "in-RAM" pays for holding the corpus and "streamed" does not —
    // which is exactly the comparison the tier is about
    let baseline = live_bytes();
    let ds = build_large_dataset(&lcfg);
    let n_samples = ds.samples.len();
    let corpus_bytes: u64 = ds.samples.iter().map(sample_bytes).sum();

    let mut w = ShardWriter::create(&dir)?;
    for s in &ds.samples {
        w.push(s)?;
    }
    w.finish(ds.stats.as_ref())?;

    let tcfg = TrainConfig {
        epochs: 1,
        seed: cfg.seed,
        verbose: false,
        node_budget,
        ..Default::default()
    };

    // in-RAM lane: resident corpus + split copies + training workspace
    reset_peak_bytes();
    let t0 = Instant::now();
    let (tr_ds, te_ds) = ds.split(0.5, cfg.seed);
    let in_ram = train(rt, &tr_ds, &te_ds, &tcfg)?;
    let in_ram_train_s = t0.elapsed().as_secs_f64();
    let in_ram_peak_bytes = peak_bytes().saturating_sub(baseline);
    drop(tr_ds);
    drop(te_ds);
    drop(ds);

    // streamed lane: the corpus lives on disk; only the index and one
    // decoded batch (plus one over-budget graph's partitions) resident
    let sd = ShardedDataset::open(&dir)?;
    reset_peak_bytes();
    let t0 = Instant::now();
    let (tv, ev) = split_source(&sd, 0.5, cfg.seed)?;
    let epoch_nodes = tv.total_nodes();
    let streamed = train_source(rt, &tv, &ev, &tcfg)?;
    let streamed_train_s = t0.elapsed().as_secs_f64();
    let streamed_peak_bytes = peak_bytes().saturating_sub(baseline);

    // correctness first: the storage paths must not change the numbers
    ensure!(
        in_ram.params.values == streamed.params.values,
        "streamed training diverged from the in-RAM loop at the {n_stages}-stage tier"
    );

    // full-graph vs partitioned step probes, windowed after the sample
    // (resp. its partitions) is resident so each window is batch build +
    // step workspace only
    let partitioned = n_stages > node_budget;
    let (mut full_step_s, mut full_step_peak_bytes) = (0.0f64, 0u64);
    let (mut part_step_s, mut part_step_peak_bytes) = (0.0f64, 0u64);
    let mut cut_edge_fraction = 0.0f64;
    if partitioned {
        let s0 = sd.fetch(0)?;
        let stats = sd.stats().context("corpus stats missing from the shard index")?;
        let best = s0.mean_runtime();
        let lr = LEARNING_RATE as f32;

        let mut p = rt.init_params(cfg.seed);
        let mut a = p.zeros_like();
        reset_peak_bytes();
        let window = live_bytes();
        let t0 = Instant::now();
        let b = PackedBatch::build(&[&s0], stats, &[best])?;
        rt.train_step_lr(&mut p, &mut a, &b, lr)?;
        full_step_s = t0.elapsed().as_secs_f64();
        full_step_peak_bytes = peak_bytes().saturating_sub(window);
        drop(b);

        let part = partition_sample(&s0, node_budget);
        cut_edge_fraction = part.cut_edge_fraction();
        let mut p = rt.init_params(cfg.seed);
        let mut a = p.zeros_like();
        reset_peak_bytes();
        let window = live_bytes();
        let t0 = Instant::now();
        for (ps, sh) in part.parts.iter().zip(&part.shares) {
            let b = PackedBatch::build(&[ps], stats, &[best * sh])?;
            rt.train_step_lr(&mut p, &mut a, &b, lr)?;
        }
        part_step_s = t0.elapsed().as_secs_f64();
        part_step_peak_bytes = peak_bytes().saturating_sub(window);
    }

    let predict = predict_lane(rt, &streamed.params, &sd, node_budget)?;

    std::fs::remove_dir_all(&dir).ok();
    Ok(TierReport {
        n_stages,
        n_samples,
        corpus_bytes,
        in_ram_train_s,
        in_ram_peak_bytes,
        streamed_train_s,
        streamed_peak_bytes,
        streamed_nodes_per_s: epoch_nodes as f64 / streamed_train_s.max(1e-9),
        partitioned,
        full_step_s,
        full_step_peak_bytes,
        part_step_s,
        part_step_peak_bytes,
        cut_edge_fraction,
        predict_chunk_ms_p50: predict.chunk_ms.quantile(50.0),
        predict_chunk_ms_p90: predict.chunk_ms.quantile(90.0),
        predict_chunk_ms_max: predict.chunk_ms.max(),
        predict_live_bytes_p50: predict.live.quantile(50.0),
        predict_live_bytes_max: predict.live.max(),
    })
}

/// Run the explicit tier list (the test entry point — `run_scale_bench`
/// supplies the 1k/10k/100k profile).
pub(crate) fn run_scale_tiers(
    cfg: &ScaleBenchConfig,
    tiers: &[(usize, u32, u32)],
) -> Result<ScaleBenchReport> {
    // the fast profile tops out at 10k stages; tighten the budget so that
    // tier still trains through several partitions per graph
    let node_budget =
        if cfg.fast { cfg.node_budget.min(2048) } else { cfg.node_budget }.max(1);
    let rt = NativeBackend::new();
    let mut reports = Vec::with_capacity(tiers.len());
    for &(n_stages, n_pipelines, scheds) in tiers {
        reports.push(run_tier(&rt, cfg, n_stages, n_pipelines, scheds, node_budget)?);
    }
    Ok(ScaleBenchReport {
        fast: cfg.fast,
        node_budget,
        style: LargeStyle::Transformer.name().to_string(),
        tiers: reports,
    })
}

/// Run the in-RAM/streamed and full-graph/partitioned comparison over
/// the scale tiers.
pub fn run_scale_bench(cfg: &ScaleBenchConfig) -> Result<ScaleBenchReport> {
    run_scale_tiers(cfg, &tier_spec(cfg.fast))
}

/// Serialize a report to `BENCH_10.json`.
pub fn write_scale_report(report: &ScaleBenchReport, path: &Path) -> Result<()> {
    let tiers: Vec<Json> = report
        .tiers
        .iter()
        .map(|t| {
            Json::obj(vec![
                ("n_stages", Json::Num(t.n_stages as f64)),
                ("n_samples", Json::Num(t.n_samples as f64)),
                ("corpus_bytes", Json::Num(t.corpus_bytes as f64)),
                (
                    "in_ram",
                    Json::obj(vec![
                        ("train_s", Json::Num(t.in_ram_train_s)),
                        ("peak_bytes", Json::Num(t.in_ram_peak_bytes as f64)),
                    ]),
                ),
                (
                    "streamed",
                    Json::obj(vec![
                        ("train_s", Json::Num(t.streamed_train_s)),
                        ("peak_bytes", Json::Num(t.streamed_peak_bytes as f64)),
                        ("nodes_per_s", Json::Num(t.streamed_nodes_per_s)),
                    ]),
                ),
                (
                    "mem_ratio_in_ram_over_streamed",
                    Json::Num(t.in_ram_peak_bytes as f64 / t.streamed_peak_bytes.max(1) as f64),
                ),
                ("partitioned", Json::Num(if t.partitioned { 1.0 } else { 0.0 })),
                ("cut_edge_fraction", Json::Num(t.cut_edge_fraction)),
                (
                    "step_peak",
                    Json::obj(vec![
                        ("full_graph_bytes", Json::Num(t.full_step_peak_bytes as f64)),
                        ("partitioned_bytes", Json::Num(t.part_step_peak_bytes as f64)),
                        ("full_graph_s", Json::Num(t.full_step_s)),
                        ("partitioned_s", Json::Num(t.part_step_s)),
                    ]),
                ),
                (
                    "predict",
                    Json::obj(vec![
                        ("chunk_ms_p50", Json::Num(t.predict_chunk_ms_p50)),
                        ("chunk_ms_p90", Json::Num(t.predict_chunk_ms_p90)),
                        ("chunk_ms_max", Json::Num(t.predict_chunk_ms_max)),
                        ("live_bytes_p50", Json::Num(t.predict_live_bytes_p50)),
                        ("live_bytes_max", Json::Num(t.predict_live_bytes_max)),
                    ]),
                ),
            ])
        })
        .collect();
    let j = Json::obj(vec![
        (
            "bench",
            Json::Str("out-of-core scale: in-RAM vs streamed, full-graph vs partitioned".into()),
        ),
        ("fast", Json::Num(if report.fast { 1.0 } else { 0.0 })),
        ("style", Json::Str(report.style.clone())),
        ("node_budget", Json::Num(report.node_budget as f64)),
        ("tiers", Json::Arr(tiers)),
    ]);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, j.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tiny_scale_bench_runs_and_reports() {
        // Debug-sized tiers; the memory/speed bars are enforced by the
        // serial CI step (`bench --fast --require-speedup`), not here —
        // parallel sibling tests pollute the process-wide peak window.
        // The bitwise streamed==in-RAM check still runs inside run_tier.
        let cfg = ScaleBenchConfig { fast: true, seed: 9, node_budget: 512 };
        let report = run_scale_tiers(&cfg, &[(300, 2, 2), (1_200, 2, 2)]).unwrap();
        assert_eq!(report.tiers.len(), 2);
        assert_eq!(report.node_budget, 512);
        let small = &report.tiers[0];
        let big = &report.tiers[1];
        assert!(!small.partitioned);
        assert!(big.partitioned, "the 1200-stage tier must exceed the 512-node budget");
        assert!(big.full_step_peak_bytes > 0 && big.part_step_peak_bytes > 0);
        assert_eq!(small.cut_edge_fraction, 0.0);
        assert!(
            big.cut_edge_fraction > 0.0 && big.cut_edge_fraction < 0.02,
            "block-local topology should cut few edges, got {}",
            big.cut_edge_fraction
        );
        assert!(big.in_ram_train_s > 0.0 && big.streamed_train_s > 0.0);
        assert!(big.streamed_nodes_per_s > 0.0);
        assert!(big.predict_chunk_ms_p50 <= big.predict_chunk_ms_max);
        assert!(big.corpus_bytes > small.corpus_bytes);

        let path = std::env::temp_dir().join("gcn_perf_bench10_test.json");
        write_scale_report(&report, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("mem_ratio_in_ram_over_streamed"));
        assert!(text.contains("chunk_ms_p50"));
        assert!(text.contains("cut_edge_fraction"));
        crate::util::json::Json::parse(&text).unwrap();
        std::fs::remove_file(&path).ok();
    }
}
