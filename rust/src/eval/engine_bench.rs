//! Native-engine micro-benchmarks — `BENCH_5.json`.
//!
//! Measures the PR-5 compute-core overhaul (workspace arena + inference
//! fast path + tiled kernels + parallel backward) against a frozen
//! snapshot of the PR-4 engine (`eval::legacy_engine`) on the two
//! canonical workloads:
//!
//! * **padded** — one `BATCH`-graph packed batch of generator pipelines
//!   (~5–10 stages each), the serving layer's common case;
//! * **resnet50** — schedules of the 59-stage zoo network, the
//!   large-graph regime where per-node kernel cost dominates.
//!
//! Per workload it times the new fast-path `infer`, the legacy (PR-4)
//! infer, the new training-path forward, and both engines' train steps;
//! it also reports the fast path's steady-state allocations/op via the
//! counting allocator ([`crate::util::alloc_count`], exact because the
//! measurement loop is single-threaded). Before any timing, both
//! engines' outputs are asserted bit-identical — a speedup over a
//! *different* model would be meaningless.
//!
//! CI runs `gcn-perf bench --fast --require-speedup`, which calls
//! [`EngineBenchReport::require_speedup`]: the new infer must beat the
//! PR-4 infer on both workloads and the new train step must win on at
//! least one. The full (non-`--fast`) run is what README's perf table
//! quotes; `scripts/profile.sh` wraps `gcn-perf bench --engine` for
//! flamegraph work on the same loops.

use crate::eval::legacy_engine::LegacyEngine;
use crate::eval::perf::{large_workload, small_workload};
use crate::model::PackedBatch;
use crate::runtime::{Backend, NativeBackend};
use crate::util::alloc_count::{thread_alloc_bytes, thread_alloc_count};
use crate::util::bench::{bench, black_box, BenchResult};
use crate::util::json::Json;
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct EngineBenchConfig {
    /// Short warmup/measure windows (CI smoke runs).
    pub fast: bool,
    pub seed: u64,
}

impl Default for EngineBenchConfig {
    fn default() -> Self {
        EngineBenchConfig { fast: false, seed: 3 }
    }
}

/// One measured engine/workload cell.
#[derive(Debug, Clone)]
pub struct EngineRow {
    pub name: String,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub graphs_per_s: f64,
}

/// The full report: rows, PR-4-over-PR-5 speedups, and the fast path's
/// steady-state allocation profile on the padded workload.
#[derive(Debug, Clone)]
pub struct EngineBenchReport {
    pub fast: bool,
    pub rows: Vec<EngineRow>,
    /// mean legacy latency / mean new latency, per workload+phase
    /// (`> 1` means the new engine wins).
    pub speedups: Vec<(String, f64)>,
    /// Heap allocations per steady-state fast-path `infer` call (padded
    /// workload, single-threaded window — exact).
    pub allocs_per_infer: f64,
    /// Bytes requested per steady-state fast-path `infer` call.
    pub alloc_bytes_per_infer: f64,
}

impl EngineBenchReport {
    /// The legacy/new ratio for a named cell, NaN if absent.
    pub fn speedup(&self, name: &str) -> f64 {
        self.speedups
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, x)| *x)
            .unwrap_or(f64::NAN)
    }

    /// The acceptance bar of the PR-5 engine rework, enforced by the
    /// serial CI bench step (`bench --require-speedup`), not by
    /// `cargo test` (which shares cores with sibling tests): the new
    /// inference fast path must beat the PR-4 engine by ≥1.5x on both
    /// workloads (the PR's acceptance criterion), and the new train step
    /// must win on at least one. `--fast` runs relax the infer bar to
    /// >1.0x — their measurement windows are too short to hold a tight
    /// ratio steady on shared CI runners.
    pub fn require_speedup(&self) -> Result<()> {
        let infer_bar = if self.fast { 1.0 } else { 1.5 };
        for workload in ["padded", "resnet50"] {
            let x = self.speedup(&format!("{workload}/infer"));
            ensure!(
                x > infer_bar,
                "new infer did not beat the PR-4 engine on {workload}: \
                 {x:.3}x (expected > {infer_bar})"
            );
        }
        let train = self
            .speedup("padded/train-step")
            .max(self.speedup("resnet50/train-step"));
        ensure!(
            train > 1.0,
            "new train step did not beat the PR-4 engine on either workload: {train:.3}x"
        );
        Ok(())
    }
}

fn durations(fast: bool) -> (Duration, Duration) {
    if fast {
        (Duration::from_millis(30), Duration::from_millis(120))
    } else {
        (Duration::from_millis(200), Duration::from_secs(1))
    }
}

fn row(r: &BenchResult, batch_graphs: usize) -> EngineRow {
    let mean = r.mean_ns();
    EngineRow {
        name: r.name.clone(),
        mean_ns: mean,
        p95_ns: r.p95_ns(),
        graphs_per_s: batch_graphs as f64 / (mean / 1e9),
    }
}

/// Steady-state allocations/op of the fast path: warm the thread-local
/// workspace, then measure a single-threaded infer loop with the
/// per-thread counters (exact regardless of concurrent threads).
fn measure_allocs(
    backend: &NativeBackend,
    params: &crate::runtime::Params,
    batch: &PackedBatch,
) -> Result<(f64, f64)> {
    for _ in 0..3 {
        backend.infer(params, batch)?;
    }
    let calls = 20u64;
    let count0 = thread_alloc_count();
    let bytes0 = thread_alloc_bytes();
    for _ in 0..calls {
        black_box(backend.infer(params, batch)?);
    }
    let count = (thread_alloc_count() - count0) as f64 / calls as f64;
    let bytes = (thread_alloc_bytes() - bytes0) as f64 / calls as f64;
    Ok((count, bytes))
}

/// Run the PR-5-vs-PR-4 engine comparison on both workloads.
pub fn run_engine_bench(cfg: &EngineBenchConfig) -> Result<EngineBenchReport> {
    let new_engine = NativeBackend::new();
    let legacy = LegacyEngine::new();
    let (small, stats) = small_workload(cfg.seed)?;
    let large = large_workload(cfg.seed ^ 0x9E37, &stats, if cfg.fast { 6 } else { 12 })?;
    let (warm, measure) = durations(cfg.fast);

    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    for (workload, batch) in [("padded", &small), ("resnet50", &large)] {
        let nb = batch.n_graphs();
        let params = new_engine.init_params(1);

        // correctness gates, outside the timed loops: a speedup over a
        // semantically different engine would be meaningless
        let z_new = new_engine.infer(&params, batch)?;
        let z_full = new_engine.infer_full(&params, batch)?;
        let z_legacy = legacy.infer(&params, batch);
        ensure!(
            z_new == z_full,
            "{workload}: fast path diverges from the training forward"
        );
        ensure!(
            z_new == z_legacy,
            "{workload}: new engine diverges from the PR-4 reference"
        );

        let infer_new = bench(&format!("{workload}/infer/new"), warm, measure, || {
            black_box(new_engine.infer(&params, batch).unwrap());
        });
        let infer_legacy = bench(&format!("{workload}/infer/legacy"), warm, measure, || {
            black_box(legacy.infer(&params, batch));
        });
        let fwd_full = bench(&format!("{workload}/forward/train-path"), warm, measure, || {
            black_box(new_engine.infer_full(&params, batch).unwrap());
        });

        let mut pn = params.clone();
        let mut an = pn.zeros_like();
        let step_new = bench(&format!("{workload}/train-step/new"), warm, measure, || {
            black_box(new_engine.train_step_lr(&mut pn, &mut an, batch, 0.01).unwrap());
        });
        let mut pl = params.clone();
        let mut al = pl.zeros_like();
        let step_legacy = bench(&format!("{workload}/train-step/legacy"), warm, measure, || {
            black_box(legacy.train_step_lr(&mut pl, &mut al, batch, 0.01));
        });

        let infer_ratio = infer_legacy.mean_ns() / infer_new.mean_ns();
        speedups.push((format!("{workload}/infer"), infer_ratio));
        let train_ratio = step_legacy.mean_ns() / step_new.mean_ns();
        speedups.push((format!("{workload}/train-step"), train_ratio));
        for r in [&infer_new, &infer_legacy, &fwd_full, &step_new, &step_legacy] {
            rows.push(row(r, nb));
        }
    }

    let params = new_engine.init_params(1);
    let (allocs_per_infer, alloc_bytes_per_infer) = measure_allocs(&new_engine, &params, &small)?;

    Ok(EngineBenchReport {
        fast: cfg.fast,
        rows,
        speedups,
        allocs_per_infer,
        alloc_bytes_per_infer,
    })
}

/// Serialize a report to `BENCH_5.json`.
pub fn write_engine_report(report: &EngineBenchReport, path: &Path) -> Result<()> {
    let rows: Vec<Json> = report
        .rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::Str(r.name.clone())),
                ("mean_ns", Json::Num(r.mean_ns)),
                ("p95_ns", Json::Num(r.p95_ns)),
                ("graphs_per_s", Json::Num(r.graphs_per_s)),
            ])
        })
        .collect();
    let speedups: Vec<Json> = report
        .speedups
        .iter()
        .map(|(name, x)| {
            Json::obj(vec![
                ("name", Json::Str(name.clone())),
                ("legacy_over_new", Json::Num(*x)),
            ])
        })
        .collect();
    let j = Json::obj(vec![
        ("bench", Json::Str("native engine: PR-5 workspace/tiled/parallel vs PR-4".into())),
        ("fast", Json::Num(if report.fast { 1.0 } else { 0.0 })),
        ("results", Json::Arr(rows)),
        ("speedups", Json::Arr(speedups)),
        ("allocs_per_infer", Json::Num(report.allocs_per_infer)),
        ("alloc_bytes_per_infer", Json::Num(report.alloc_bytes_per_infer)),
    ]);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, j.to_string()).with_context(|| format!("write {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_engine_bench_runs_and_reports() {
        // Structure + the built-in bitwise correctness gates only. The
        // wall-clock acceptance bar (new engine beats PR-4) is enforced
        // by the serial CI bench step `gcn-perf bench --fast
        // --require-speedup`, not here — `cargo test` shares cores with
        // sibling tests, which poisons measurement windows.
        let report = run_engine_bench(&EngineBenchConfig { fast: true, seed: 5 }).unwrap();
        assert_eq!(report.rows.len(), 10);
        assert!(report.rows.iter().all(|r| r.mean_ns > 0.0 && r.graphs_per_s > 0.0));
        assert_eq!(report.speedups.len(), 4);
        for (name, x) in &report.speedups {
            assert!(x.is_finite() && *x > 0.0, "{name} ratio is {x}");
        }
        assert!(report.allocs_per_infer >= 0.0);
        assert!(report.speedup("padded/infer").is_finite());
        assert!(report.speedup("no-such-cell").is_nan());
        eprintln!(
            "engine speedups: padded infer {:.2}x, resnet50 infer {:.2}x, allocs/op {:.1}",
            report.speedup("padded/infer"),
            report.speedup("resnet50/infer"),
            report.allocs_per_infer
        );

        let path = std::env::temp_dir().join("gcn_perf_bench5_test.json");
        write_engine_report(&report, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("legacy_over_new"));
        assert!(text.contains("allocs_per_infer"));
        crate::util::json::Json::parse(&text).unwrap();
        std::fs::remove_file(&path).ok();
    }
}
