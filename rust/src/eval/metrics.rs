//! Fig 8 metrics: prediction quality of a performance model on a test set.

use crate::util::stats;

#[derive(Debug, Clone)]
pub struct RegressionMetrics {
    pub model: String,
    /// Mean absolute percentage error (Fig 8a, lower is better).
    pub avg_error_pct: f64,
    /// Maximum absolute percentage error (Fig 8b).
    pub max_error_pct: f64,
    /// Coefficient of determination on log-runtimes (Fig 8c, higher is
    /// better; log space because runtimes span ~4 decades — R² on raw
    /// seconds is dominated by the single largest pipeline).
    pub r2: f64,
    pub n: usize,
}

/// Compute the Fig 8 metric triple for one model's predictions.
pub fn regression_metrics(model: &str, y_true: &[f64], y_pred: &[f64]) -> RegressionMetrics {
    assert_eq!(y_true.len(), y_pred.len());
    let log_t: Vec<f64> = y_true.iter().map(|t| t.max(1e-12).ln()).collect();
    let log_p: Vec<f64> = y_pred.iter().map(|p| p.max(1e-12).ln()).collect();
    RegressionMetrics {
        model: model.to_string(),
        avg_error_pct: stats::mape(y_true, y_pred),
        max_error_pct: stats::max_ape(y_true, y_pred),
        r2: stats::r2_score(&log_t, &log_p),
        n: y_true.len(),
    }
}

impl RegressionMetrics {
    pub fn row(&self) -> String {
        format!(
            "{:<12} {:>12.2} {:>14.1} {:>8.4} {:>8}",
            self.model, self.avg_error_pct, self.max_error_pct, self.r2, self.n
        )
    }

    pub fn header() -> String {
        format!(
            "{:<12} {:>12} {:>14} {:>8} {:>8}",
            "model", "avg err %", "max err %", "R2", "n"
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_predictions() {
        let y = [1e-3, 2e-3, 5e-2];
        let m = regression_metrics("x", &y, &y);
        assert!(m.avg_error_pct < 1e-9);
        assert!(m.max_error_pct < 1e-9);
        assert!((m.r2 - 1.0).abs() < 1e-12);
    }

    #[test]
    fn constant_predictor_has_zero_r2() {
        let y = [1e-3, 2e-3, 4e-3, 8e-3];
        let geo = (1e-3f64 * 2e-3 * 4e-3 * 8e-3).powf(0.25);
        let p = [geo; 4];
        let m = regression_metrics("x", &y, &p);
        assert!(m.r2.abs() < 1e-9, "r2 {}", m.r2);
        assert!(m.avg_error_pct > 10.0);
    }

    #[test]
    fn ten_percent_error() {
        let y = [1.0, 2.0];
        let p = [1.1, 2.2];
        let m = regression_metrics("x", &y, &p);
        assert!((m.avg_error_pct - 10.0).abs() < 1e-9);
        assert!((m.max_error_pct - 10.0).abs() < 1e-6);
    }
}
