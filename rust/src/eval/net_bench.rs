//! Network-serving benchmark — `BENCH_6.json`.
//!
//! Stands the full TCP front-end up in-process (real sockets on a
//! loopback ephemeral port), hammers it with the [`loadgen`] client
//! fleet over a mixed-size sample pool (generator pipelines plus
//! resnet50 schedules, as in `serve_bench`), and reports throughput and
//! the latency histogram. Correctness is not sampled, it is total:
//! every response is verified **bitwise** against direct
//! `Predictor::predict` on the same samples, so the whole stack —
//! framing, JSON round-trip, pipelining, coalesced batching — must be
//! prediction-preserving before any number is trusted. The server
//! stats in the report come over the wire via `STATS`, exercising that
//! path end-to-end too.
//!
//! CI runs the `--fast` variant via `gcn-perf loadgen --fast
//! --min-rps ...`, which asserts a throughput floor; like the other
//! benches, the floor is enforced by that serial CI step and not by
//! `cargo test`.

use crate::dataset::builder::{build_dataset, sample_from_schedule, DataGenConfig};
use crate::dataset::sample::GraphSample;
use crate::lower::lower_pipeline;
use crate::net::loadgen::{fetch_stats, run_loadgen, LoadgenConfig, LoadgenReport};
use crate::net::server::{TcpServer, TcpServerConfig};
use crate::net::session::ServeShared;
use crate::predictor::{GcnPredictor, PredictService, Predictor, ServiceConfig};
use crate::runtime::{Backend, NativeBackend};
use crate::schedule::random::random_pipeline_schedule;
use crate::sim::Machine;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::sync::atomic::AtomicBool;
use std::sync::Arc;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct NetBenchConfig {
    /// Short run (CI smoke).
    pub fast: bool,
    pub seed: u64,
}

impl Default for NetBenchConfig {
    fn default() -> Self {
        NetBenchConfig { fast: false, seed: 3 }
    }
}

/// One benchmark run: the loadgen aggregate plus the server's own view.
#[derive(Debug, Clone)]
pub struct NetBenchReport {
    pub fast: bool,
    pub workload: LoadgenConfig,
    pub loadgen: LoadgenReport,
    /// The `STATS` response object, fetched over the wire.
    pub server_stats: Option<Json>,
}

impl NetBenchReport {
    /// Error unless aggregate throughput met `min_rps` (see
    /// [`LoadgenReport::require_throughput`]).
    pub fn require_throughput(&self, min_rps: f64) -> Result<()> {
        self.loadgen.require_throughput(min_rps)
    }
}

/// The mixed-size sample pool: every sample from a small generated
/// dataset, interleaved with >48-stage resnet50 schedules.
pub fn build_pool(seed: u64) -> Result<(Arc<dyn Predictor>, Vec<GraphSample>)> {
    let ds = build_dataset(&DataGenConfig {
        n_pipelines: 8,
        schedules_per_pipeline: 4,
        seed,
        ..Default::default()
    });
    let stats = ds.stats.clone().context("dataset stats")?;

    let net = crate::zoo::resnet50();
    let nests = lower_pipeline(&net);
    let machine = Machine::default();
    let mut rng = Rng::new(seed ^ 0x6E7);
    let mut pool = ds.samples;
    for sid in 0..4u32 {
        let sched = random_pipeline_schedule(&net, &nests, &mut rng);
        pool.push(sample_from_schedule(&net, &nests, &sched, &machine, 1000, sid, &mut rng));
    }

    let backend = NativeBackend::new();
    let params = backend.init_params(seed);
    let predictor: Arc<dyn Predictor> =
        Arc::new(GcnPredictor::new(Box::new(backend), params, stats));
    Ok((predictor, pool))
}

/// Run the in-process server + client fleet and gather the report.
pub fn run_net_bench(cfg: &NetBenchConfig) -> Result<NetBenchReport> {
    let workload = if cfg.fast {
        LoadgenConfig {
            clients: 8,
            requests_per_client: 16,
            samples_per_request: 3,
            pipeline_depth: 4,
            ..Default::default()
        }
    } else {
        LoadgenConfig {
            clients: 96,
            requests_per_client: 40,
            samples_per_request: 4,
            pipeline_depth: 8,
            ..Default::default()
        }
    };

    let (predictor, pool) = build_pool(cfg.seed)?;
    let refs: Vec<&GraphSample> = pool.iter().collect();
    let expected = predictor.predict(&refs)?;

    let service = Arc::new(PredictService::spawn(
        Arc::clone(&predictor),
        ServiceConfig { queue_cap: workload.clients.max(8), ..Default::default() },
    ));
    let shared = ServeShared::new(service);
    let shutdown = Arc::new(AtomicBool::new(false));
    let server = TcpServer::bind(
        "127.0.0.1:0",
        shared,
        TcpServerConfig {
            max_conns: workload.clients + 8,
            read_timeout: Some(Duration::from_secs(60)),
            ..Default::default()
        },
        Arc::clone(&shutdown),
    )?;
    let addr = server.local_addr().to_string();

    let loadgen = run_loadgen(&addr, &pool, Some(&expected), &workload)?;
    // no drain ran during the measured load, so the accounting must close
    ensure!(
        loadgen.responses_ok + loadgen.responses_err == loadgen.requests_sent,
        "lost responses: {} sent, {} answered",
        loadgen.requests_sent,
        loadgen.responses_ok + loadgen.responses_err
    );
    ensure!(
        loadgen.responses_err == 0,
        "{} error responses under clean load",
        loadgen.responses_err
    );
    ensure!(
        loadgen.bitwise_verified == loadgen.responses_ok,
        "only {}/{} responses verified bitwise",
        loadgen.bitwise_verified,
        loadgen.responses_ok
    );

    let server_stats = fetch_stats(&addr).ok();
    server.shutdown_now();
    server.join()?;

    Ok(NetBenchReport { fast: cfg.fast, workload, loadgen, server_stats })
}

/// Serialize a report to `BENCH_6.json`.
pub fn write_net_report(report: &NetBenchReport, path: &Path) -> Result<()> {
    let w = &report.workload;
    let l = &report.loadgen;
    let j = Json::obj(vec![
        ("bench", Json::Str("net: multi-client TCP serving under loadgen".into())),
        ("fast", Json::Num(if report.fast { 1.0 } else { 0.0 })),
        ("clients", Json::Num(w.clients as f64)),
        ("requests_per_client", Json::Num(w.requests_per_client as f64)),
        ("samples_per_request", Json::Num(w.samples_per_request as f64)),
        ("rate_per_client", Json::Num(w.rate_per_client)),
        ("pipeline_depth", Json::Num(w.pipeline_depth as f64)),
        ("requests_sent", Json::Num(l.requests_sent as f64)),
        ("responses_ok", Json::Num(l.responses_ok as f64)),
        ("responses_err", Json::Num(l.responses_err as f64)),
        ("bitwise_verified", Json::Num(l.bitwise_verified as f64)),
        ("samples_scored", Json::Num(l.samples_scored as f64)),
        ("wall_ns", Json::Num(l.wall_ns)),
        ("requests_per_s", Json::Num(l.requests_per_s)),
        ("samples_per_s", Json::Num(l.samples_per_s)),
        ("latency", l.latency.to_json()),
        ("server", report.server_stats.clone().unwrap_or(Json::Null)),
    ]);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, j.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_net_bench_serves_verifies_and_reports() {
        // Structure + the built-in bitwise verification. The throughput
        // floor is enforced by the serial CI step (`loadgen --fast
        // --min-rps ...`), not here — `cargo test` shares cores.
        let report = run_net_bench(&NetBenchConfig { fast: true, seed: 11 }).unwrap();
        let total = report.workload.clients * report.workload.requests_per_client;
        assert_eq!(report.loadgen.requests_sent, total);
        assert_eq!(report.loadgen.responses_ok, total);
        assert_eq!(report.loadgen.bitwise_verified, total);
        assert!(report.loadgen.requests_per_s > 0.0);
        assert!(report.loadgen.latency.p50_ns > 0.0);
        assert!(report.loadgen.latency.p99_ns >= report.loadgen.latency.p50_ns);
        let stats = report.server_stats.as_ref().expect("STATS over the wire");
        let served =
            stats.get("stats").and_then(|s| s.get("requests")).and_then(|v| v.as_usize());
        assert_eq!(served, Some(total));

        let path = std::env::temp_dir().join("gcn_perf_bench6_test.json");
        write_net_report(&report, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        for key in ["requests_per_s", "p50_ns", "p99_ns", "histogram", "bitwise_verified"] {
            assert!(text.contains(key), "missing {key}");
        }
        Json::parse(&text).unwrap();
        std::fs::remove_file(&path).ok();
    }
}
