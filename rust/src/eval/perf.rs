//! Dense-vs-sparse engine benchmarks — the repo's perf trajectory.
//!
//! `gcn-perf bench` runs the sparse native engine and the dense padded
//! reference over identical packed batches and writes `BENCH_3.json`:
//! forward and train-step latency on a small-graph workload (the padded
//! regime the dense layout was built for — every graph far below the 48
//! stage pad width) and on a large-graph workload (graphs past the old
//! `MAX_NODES` cap, which the dense layout must widen to fit). CI runs
//! the `--fast` variant as a smoke test so the comparison can never rot.

use crate::constants::BATCH;
use crate::dataset::builder::{build_dataset, sample_from_schedule, DataGenConfig};
use crate::dataset::sample::GraphSample;
use crate::features::normalize::FeatureStats;
use crate::lower::lower_pipeline;
use crate::model::PackedBatch;
use crate::runtime::{Backend, DenseRefBackend, NativeBackend};
use crate::schedule::random::random_pipeline_schedule;
use crate::sim::Machine;
use crate::util::bench::{bench, black_box, BenchResult};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{Context, Result};
use std::path::Path;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct PerfBenchConfig {
    /// Short warmup/measure windows (CI smoke runs).
    pub fast: bool,
    pub seed: u64,
}

impl Default for PerfBenchConfig {
    fn default() -> Self {
        PerfBenchConfig { fast: false, seed: 3 }
    }
}

/// One measured engine/workload cell.
#[derive(Debug, Clone)]
pub struct PerfRow {
    pub name: String,
    pub mean_ns: f64,
    pub p95_ns: f64,
    /// Graphs scored (forward) or stepped (train) per second, derived
    /// from the mean latency and the workload's batch size.
    pub graphs_per_s: f64,
}

/// The full report: rows plus the dense/sparse speedup ratios the
/// acceptance bar reads.
#[derive(Debug, Clone)]
pub struct PerfReport {
    pub fast: bool,
    pub rows: Vec<PerfRow>,
    /// mean dense latency / mean sparse latency, per workload+phase.
    pub speedups: Vec<(String, f64)>,
}

fn durations(fast: bool) -> (Duration, Duration) {
    if fast {
        (Duration::from_millis(30), Duration::from_millis(120))
    } else {
        (Duration::from_millis(200), Duration::from_secs(1))
    }
}

fn row(r: &BenchResult, batch_graphs: usize) -> PerfRow {
    let mean = r.mean_ns();
    PerfRow {
        name: r.name.clone(),
        mean_ns: mean,
        p95_ns: r.p95_ns(),
        graphs_per_s: batch_graphs as f64 / (mean / 1e9),
    }
}

/// The small-graph workload: one `BATCH`-graph packed batch from the
/// standard generator (graphs of ~5–10 stages — the padded regime).
/// Shared with the engine micro-bench (`eval::engine_bench`).
pub(crate) fn small_workload(seed: u64) -> Result<(PackedBatch, FeatureStats)> {
    let ds = build_dataset(&DataGenConfig {
        n_pipelines: 8,
        schedules_per_pipeline: 4,
        seed,
        ..Default::default()
    });
    let stats = ds.stats.clone().context("dataset stats")?;
    let best = ds.best_per_pipeline();
    let refs: Vec<&GraphSample> = ds.samples.iter().take(BATCH).collect();
    let bests: Vec<f64> = refs.iter().map(|s| best[&s.pipeline_id]).collect();
    let batch = PackedBatch::build(&refs, &stats, &bests)?;
    Ok((batch, stats))
}

/// The large-graph workload: schedules of the >48-stage zoo network —
/// graphs the dense layout cannot hold at its old pad width at all.
/// Shared with the engine micro-bench (`eval::engine_bench`).
pub(crate) fn large_workload(
    seed: u64,
    stats: &FeatureStats,
    n_graphs: usize,
) -> Result<PackedBatch> {
    let net = crate::zoo::resnet50();
    let nests = lower_pipeline(&net);
    let machine = Machine::default();
    let mut rng = Rng::new(seed);
    let mut samples = Vec::with_capacity(n_graphs);
    for sid in 0..n_graphs {
        let sched = random_pipeline_schedule(&net, &nests, &mut rng);
        samples.push(sample_from_schedule(
            &net, &nests, &sched, &machine, 0, sid as u32, &mut rng,
        ));
    }
    let refs: Vec<&GraphSample> = samples.iter().collect();
    let best = refs
        .iter()
        .map(|s| s.mean_runtime())
        .fold(f64::INFINITY, f64::min);
    PackedBatch::build(&refs, stats, &vec![best; refs.len()])
}

/// Time a forward closure and a train-step closure for one
/// engine/workload cell, appending the report rows.
fn bench_pair<FwdF: FnMut(), StepF: FnMut()>(
    workload: &str,
    tag: &str,
    nb: usize,
    fast: bool,
    rows: &mut Vec<PerfRow>,
    fwd_f: FwdF,
    step_f: StepF,
) -> (f64, f64) {
    let (warm, measure) = durations(fast);
    let fwd = bench(&format!("{workload}/forward/{tag}"), warm, measure, fwd_f);
    let step = bench(&format!("{workload}/train-step/{tag}"), warm, measure, step_f);
    rows.push(row(&fwd, nb));
    rows.push(row(&step, nb));
    (fwd.mean_ns(), step.mean_ns())
}

/// Run the dense-vs-sparse comparison on both workloads.
///
/// Both engines consume the identical packed batch; the dense side is
/// converted to its padded layout once, *outside* the timed loops — the
/// pre-sparse engine consumed ready-built dense batches, so timing the
/// converter would overstate the sparse engine's win.
pub fn run_perf_bench(cfg: &PerfBenchConfig) -> Result<PerfReport> {
    let sparse = NativeBackend::new();
    let dense = DenseRefBackend::new();
    let (small, stats) = small_workload(cfg.seed)?;
    let large = large_workload(cfg.seed ^ 0x9E37, &stats, if cfg.fast { 4 } else { 8 })?;

    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    for (workload, batch) in [("small-graphs", &small), ("large-graphs", &large)] {
        let nb = batch.n_graphs();
        let params = sparse.init_params(1);
        // fail fast (outside the timed loops) so a broken engine cannot
        // silently bench garbage
        sparse.infer(&params, batch)?;
        let dense_batch = dense.to_dense(batch)?;
        dense.infer_dense(&params, &dense_batch)?;

        let mut sp = params.clone();
        let mut sa = sp.zeros_like();
        let (sf, st) = bench_pair(
            workload,
            "sparse",
            nb,
            cfg.fast,
            &mut rows,
            || {
                black_box(sparse.infer(&params, batch).unwrap());
            },
            || {
                black_box(sparse.train_step_lr(&mut sp, &mut sa, batch, 0.01).unwrap());
            },
        );
        let mut dp = params.clone();
        let mut da = dp.zeros_like();
        let (df, dt) = bench_pair(
            workload,
            "dense",
            nb,
            cfg.fast,
            &mut rows,
            || {
                black_box(dense.infer_dense(&params, &dense_batch).unwrap());
            },
            || {
                black_box(
                    dense.train_step_dense(&mut dp, &mut da, &dense_batch, 0.01).unwrap(),
                );
            },
        );
        speedups.push((format!("{workload}/forward"), df / sf));
        speedups.push((format!("{workload}/train-step"), dt / st));
    }
    Ok(PerfReport { fast: cfg.fast, rows, speedups })
}

impl PerfReport {
    /// The dense/sparse forward ratio on the padded (small-graph)
    /// workload — the acceptance bar of the sparse rewrite.
    pub fn padded_forward_speedup(&self) -> f64 {
        self.speedups
            .iter()
            .find(|(n, _)| n == "small-graphs/forward")
            .map(|(_, x)| *x)
            .unwrap_or(f64::NAN)
    }

    /// Error unless the sparse forward beat the dense padded path on the
    /// padded workload. Used by the serial CI bench step
    /// (`bench --require-speedup`) rather than by `cargo test`, so the
    /// test suite stays deterministic on noisy shared runners.
    pub fn require_padded_speedup(&self) -> Result<()> {
        let x = self.padded_forward_speedup();
        anyhow::ensure!(
            x > 1.0,
            "sparse forward did not beat the dense padded path: {x:.3}x (expected > 1.0)"
        );
        Ok(())
    }
}

/// Serialize a report to `BENCH_3.json`.
pub fn write_perf_report(report: &PerfReport, path: &Path) -> Result<()> {
    let rows: Vec<Json> = report
        .rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::Str(r.name.clone())),
                ("mean_ns", Json::Num(r.mean_ns)),
                ("p95_ns", Json::Num(r.p95_ns)),
                ("graphs_per_s", Json::Num(r.graphs_per_s)),
            ])
        })
        .collect();
    let speedups: Vec<Json> = report
        .speedups
        .iter()
        .map(|(name, x)| {
            Json::obj(vec![
                ("name", Json::Str(name.clone())),
                ("dense_over_sparse", Json::Num(*x)),
            ])
        })
        .collect();
    let j = Json::obj(vec![
        ("bench", Json::Str("dense-vs-sparse graph batching".into())),
        ("fast", Json::Num(if report.fast { 1.0 } else { 0.0 })),
        ("results", Json::Arr(rows)),
        ("speedups", Json::Arr(speedups)),
    ]);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, j.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_bench_runs_and_reports() {
        // Structure + sanity only. The wall-clock acceptance bar (sparse
        // forward > dense on the padded workload) is deliberately NOT
        // asserted here: `cargo test` runs tests in parallel on shared
        // runners, where sibling tests can poison a measurement window.
        // The serial CI bench step enforces it via
        // `gcn-perf bench --require-speedup`.
        let report = run_perf_bench(&PerfBenchConfig { fast: true, seed: 5 }).unwrap();
        assert_eq!(report.rows.len(), 8);
        assert!(report.rows.iter().all(|r| r.mean_ns > 0.0 && r.graphs_per_s > 0.0));
        assert_eq!(report.speedups.len(), 4);
        let fwd_small = report.padded_forward_speedup();
        assert!(fwd_small.is_finite() && fwd_small > 0.0);
        eprintln!("padded-workload forward speedup (dense/sparse): {fwd_small:.2}x");

        let path = std::env::temp_dir().join("gcn_perf_bench3_test.json");
        write_perf_report(&report, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("dense_over_sparse"));
        crate::util::json::Json::parse(&text).unwrap();
        std::fs::remove_file(&path).ok();
    }
}
