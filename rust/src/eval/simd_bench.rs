//! SIMD / int8 engine benchmarks — `BENCH_8.json`.
//!
//! Three-way comparison of the native engine's inference lanes on the
//! two canonical workloads (`padded`, `resnet50` — shared with
//! `eval::engine_bench`):
//!
//! * **scalar** — the bitwise-deterministic f32 reference (the PR-5
//!   fast path, still the default everywhere);
//! * **simd** — the same f32 math through the best runtime-detected
//!   microkernel tier (`sse2`/`avx2` with the `simd` cargo feature on
//!   x86_64; identical to scalar otherwise);
//! * **int8** — the reduced-precision path (per-channel int8 weights,
//!   f32 accumulation) on the same detected tier.
//!
//! Per lane it reports infer latency, throughput and steady-state
//! allocations/op; numeric-mode validation runs before any timing and
//! is unconditional: SIMD must match scalar within
//! [`SIMD_REL_TOL`](crate::runtime::kernels_simd::SIMD_REL_TOL) per
//! output, int8 must stay inside the z-envelope declared in
//! [`crate::runtime::quant`], and int8 predictions on zoo (resnet50)
//! schedules must agree with f32 rankings at
//! [`INT8_RANK_AGREEMENT_MIN`] or better — a fast lane that answers a
//! different model is worthless. The wall-clock gates
//! ([`SimdBenchReport::require_speedup`]) run only in the serial CI
//! bench step and are skipped (with a note) when the build resolves to
//! scalar kernels, where there is no speedup to assert.

use crate::dataset::builder::sample_from_schedule;
use crate::dataset::sample::GraphSample;
use crate::eval::metrics::regression_metrics;
use crate::eval::perf::{large_workload, small_workload};
use crate::eval::ranking::pairwise_ranking_accuracy;
use crate::lower::lower_pipeline;
use crate::model::PackedBatch;
use crate::runtime::kernels_simd::{detected, KernelVariant, SIMD_REL_TOL};
use crate::runtime::quant::{INT8_RANK_AGREEMENT_MIN, INT8_Z_ABS_TOL, INT8_Z_REL_TOL};
use crate::runtime::{Backend, NativeBackend, QuantParams};
use crate::schedule::random::random_pipeline_schedule;
use crate::sim::Machine;
use crate::util::alloc_count::{thread_alloc_bytes, thread_alloc_count};
use crate::util::bench::{bench, black_box, BenchResult};
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::time::Duration;

#[derive(Debug, Clone)]
pub struct SimdBenchConfig {
    /// Short warmup/measure windows (CI smoke runs).
    pub fast: bool,
    pub seed: u64,
}

impl Default for SimdBenchConfig {
    fn default() -> Self {
        SimdBenchConfig { fast: false, seed: 3 }
    }
}

/// One measured lane/workload cell.
#[derive(Debug, Clone)]
pub struct SimdRow {
    pub name: String,
    pub mean_ns: f64,
    pub p95_ns: f64,
    pub graphs_per_s: f64,
}

/// Steady-state allocation profile of one lane (padded workload).
#[derive(Debug, Clone)]
pub struct LaneAllocs {
    pub lane: String,
    pub allocs_per_infer: f64,
    pub alloc_bytes_per_infer: f64,
}

/// The full three-way report.
#[derive(Debug, Clone)]
pub struct SimdBenchReport {
    pub fast: bool,
    /// The microkernel tier the simd and int8 lanes actually ran on
    /// ("scalar" in a default build — then the speed gates are moot).
    pub variant: String,
    pub rows: Vec<SimdRow>,
    /// mean scalar latency / mean lane latency, per workload+lane
    /// (`> 1` means the lane wins).
    pub speedups: Vec<(String, f64)>,
    pub allocs: Vec<LaneAllocs>,
    /// Largest per-output relative deviation of the SIMD lane from
    /// scalar, across both workloads (gated at `SIMD_REL_TOL`).
    pub max_rel_dev_simd: f64,
    /// Largest absolute log-runtime deviation of the int8 lane from
    /// scalar f32, across both workloads (gated by the z-envelope).
    pub max_z_dev_int8: f64,
    /// Pairwise ranking agreement of int8 vs f32 predictions on zoo
    /// (resnet50) schedules, as a fraction in [0, 1].
    pub int8_rank_agreement: f64,
    /// MAPE of f32 and int8 predictions against the zoo samples' mean
    /// measured runtimes — the end-to-end prediction-error delta int8
    /// costs.
    pub mape_f32: f64,
    pub mape_int8: f64,
}

impl SimdBenchReport {
    /// The scalar/lane ratio for a named cell, NaN if absent.
    pub fn speedup(&self, name: &str) -> f64 {
        self.speedups
            .iter()
            .find(|(n, _)| n == name)
            .map(|(_, x)| *x)
            .unwrap_or(f64::NAN)
    }

    /// The wall-clock acceptance bar of the SIMD microkernel layer,
    /// enforced by the serial CI bench step (`bench --require-speedup`),
    /// not by `cargo test`: the SIMD f32 lane must beat scalar by ≥1.5x
    /// on both workloads (>1.0x in `--fast` runs — short windows on
    /// shared runners cannot hold a tight ratio), and int8 must be at
    /// least as fast as SIMD f32 (within 20% in `--fast` runs). When the
    /// build resolves to scalar kernels there is no speedup to assert;
    /// the gates are skipped with a note. The numeric-mode gates are NOT
    /// here — they run unconditionally inside [`run_simd_bench`].
    pub fn require_speedup(&self) -> Result<()> {
        if self.variant == KernelVariant::Scalar.as_str() {
            eprintln!(
                "simd bench: kernels resolved to scalar (no `simd` feature or no CPU \
                 support) — speed gates skipped, numeric gates already ran"
            );
            return Ok(());
        }
        let simd_bar = if self.fast { 1.0 } else { 1.5 };
        let int8_factor = if self.fast { 0.8 } else { 1.0 };
        for workload in ["padded", "resnet50"] {
            let simd = self.speedup(&format!("{workload}/simd"));
            ensure!(
                simd > simd_bar,
                "simd infer did not beat scalar on {workload}: {simd:.3}x \
                 (expected > {simd_bar})"
            );
            let int8 = self.speedup(&format!("{workload}/int8"));
            ensure!(
                int8 >= simd * int8_factor,
                "int8 infer fell behind simd f32 on {workload}: {int8:.3}x vs \
                 {simd:.3}x (expected >= {:.3}x)",
                simd * int8_factor
            );
        }
        Ok(())
    }
}

fn durations(fast: bool) -> (Duration, Duration) {
    if fast {
        (Duration::from_millis(30), Duration::from_millis(120))
    } else {
        (Duration::from_millis(200), Duration::from_secs(1))
    }
}

fn row(r: &BenchResult, batch_graphs: usize) -> SimdRow {
    let mean = r.mean_ns();
    SimdRow {
        name: r.name.clone(),
        mean_ns: mean,
        p95_ns: r.p95_ns(),
        graphs_per_s: batch_graphs as f64 / (mean / 1e9),
    }
}

/// Steady-state allocations/op of one lane: warm the thread-local
/// workspace, then measure a single-threaded loop with the per-thread
/// counters (exact regardless of concurrent threads).
fn measure_allocs(mut f: impl FnMut()) -> (f64, f64) {
    for _ in 0..3 {
        f();
    }
    let calls = 20u64;
    let count0 = thread_alloc_count();
    let bytes0 = thread_alloc_bytes();
    for _ in 0..calls {
        f();
    }
    let count = (thread_alloc_count() - count0) as f64 / calls as f64;
    let bytes = (thread_alloc_bytes() - bytes0) as f64 / calls as f64;
    (count, bytes)
}

/// Random schedules of the 59-stage zoo network, with their simulated
/// runtimes — the end-to-end sample set the prediction-error and
/// ranking gates run on.
fn zoo_samples(seed: u64, n: usize) -> Vec<GraphSample> {
    let net = crate::zoo::resnet50();
    let nests = lower_pipeline(&net);
    let machine = Machine::default();
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|sid| {
            let sched = random_pipeline_schedule(&net, &nests, &mut rng);
            sample_from_schedule(&net, &nests, &sched, &machine, 0, sid as u32, &mut rng)
        })
        .collect()
}

/// Run the scalar/SIMD/int8 comparison on both workloads, including the
/// unconditional numeric-mode gates.
pub fn run_simd_bench(cfg: &SimdBenchConfig) -> Result<SimdBenchReport> {
    let scalar = NativeBackend::new();
    let tuned = NativeBackend::with_variant(detected());
    let variant = tuned.kernel_variant();
    let (small, stats) = small_workload(cfg.seed)?;
    let large = large_workload(cfg.seed ^ 0x9E37, &stats, if cfg.fast { 6 } else { 12 })?;
    let (warm, measure) = durations(cfg.fast);

    let params = scalar.init_params(1);
    let qp = QuantParams::from_params(&params, scalar.manifest().n_conv)?;

    let mut rows = Vec::new();
    let mut speedups = Vec::new();
    let mut max_rel_dev_simd = 0f64;
    let mut max_z_dev_int8 = 0f64;
    for (workload, batch) in [("padded", &small), ("resnet50", &large)] {
        let nb = batch.n_graphs();

        // numeric-mode gates, outside the timed loops and unconditional:
        // a fast lane answering a different model would be meaningless
        let z_scalar = scalar.infer(&params, batch)?;
        let z_simd = tuned.infer(&params, batch)?;
        for (s, v) in z_scalar.iter().zip(&z_simd) {
            let dev = (*v as f64 - *s as f64).abs() / (*s as f64).abs().max(1.0);
            max_rel_dev_simd = max_rel_dev_simd.max(dev);
            ensure!(
                dev <= SIMD_REL_TOL,
                "{workload}: {} infer deviates {dev:.2e} from scalar \
                 (envelope {SIMD_REL_TOL:.0e})",
                variant.as_str()
            );
        }
        let z_int8 = tuned.infer_quant(&qp, batch)?;
        for (s, v) in z_scalar.iter().zip(&z_int8) {
            let dev = (*v as f64 - *s as f64).abs();
            let tol = INT8_Z_ABS_TOL + INT8_Z_REL_TOL * (*s as f64).abs();
            max_z_dev_int8 = max_z_dev_int8.max(dev);
            ensure!(
                dev <= tol,
                "{workload}: int8 log-runtime deviates {dev:.4} from f32 \
                 (envelope {tol:.4})"
            );
        }

        let scalar_r = bench(&format!("{workload}/infer/scalar"), warm, measure, || {
            black_box(scalar.infer(&params, batch).unwrap());
        });
        let simd_r = bench(&format!("{workload}/infer/simd"), warm, measure, || {
            black_box(tuned.infer(&params, batch).unwrap());
        });
        let int8_r = bench(&format!("{workload}/infer/int8"), warm, measure, || {
            black_box(tuned.infer_quant(&qp, batch).unwrap());
        });
        speedups.push((format!("{workload}/simd"), scalar_r.mean_ns() / simd_r.mean_ns()));
        speedups.push((format!("{workload}/int8"), scalar_r.mean_ns() / int8_r.mean_ns()));
        for r in [&scalar_r, &simd_r, &int8_r] {
            rows.push(row(r, nb));
        }
    }

    let allocs = vec![
        lane_allocs("scalar", || {
            black_box(scalar.infer(&params, &small).unwrap());
        }),
        lane_allocs("simd", || {
            black_box(tuned.infer(&params, &small).unwrap());
        }),
        lane_allocs("int8", || {
            black_box(tuned.infer_quant(&qp, &small).unwrap());
        }),
    ];

    // end-to-end on zoo schedules: prediction-error delta and ranking
    // agreement of the reduced-precision path against full f32
    let zoo = zoo_samples(cfg.seed ^ 0xC0FFEE, if cfg.fast { 24 } else { 64 });
    let refs: Vec<&GraphSample> = zoo.iter().collect();
    let truth: Vec<f64> = refs.iter().map(|s| s.mean_runtime()).collect();
    let pred_f32 = scalar.predict_runtimes(&params, &refs, &stats)?;
    let pred_int8 = tuned.predict_runtimes_quant(&qp, &refs, &stats)?;
    let mape_f32 = regression_metrics("gcn-f32", &truth, &pred_f32).avg_error_pct;
    let mape_int8 = regression_metrics("gcn-int8", &truth, &pred_int8).avg_error_pct;
    let rank = pairwise_ranking_accuracy("int8-vs-f32", &pred_f32, &pred_int8, 0.01);
    let int8_rank_agreement = rank.accuracy_pct() / 100.0;
    ensure!(
        int8_rank_agreement >= INT8_RANK_AGREEMENT_MIN,
        "int8 ranking agreement with f32 is {int8_rank_agreement:.3} on zoo schedules \
         (declared minimum {INT8_RANK_AGREEMENT_MIN})"
    );

    Ok(SimdBenchReport {
        fast: cfg.fast,
        variant: variant.as_str().into(),
        rows,
        speedups,
        allocs,
        max_rel_dev_simd,
        max_z_dev_int8,
        int8_rank_agreement,
        mape_f32,
        mape_int8,
    })
}

fn lane_allocs(lane: &str, f: impl FnMut()) -> LaneAllocs {
    let (allocs_per_infer, alloc_bytes_per_infer) = measure_allocs(f);
    LaneAllocs { lane: lane.into(), allocs_per_infer, alloc_bytes_per_infer }
}

/// Serialize a report to `BENCH_8.json`.
pub fn write_simd_report(report: &SimdBenchReport, path: &Path) -> Result<()> {
    let rows: Vec<Json> = report
        .rows
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("name", Json::Str(r.name.clone())),
                ("mean_ns", Json::Num(r.mean_ns)),
                ("p95_ns", Json::Num(r.p95_ns)),
                ("graphs_per_s", Json::Num(r.graphs_per_s)),
            ])
        })
        .collect();
    let speedups: Vec<Json> = report
        .speedups
        .iter()
        .map(|(name, x)| {
            Json::obj(vec![
                ("name", Json::Str(name.clone())),
                ("scalar_over_lane", Json::Num(*x)),
            ])
        })
        .collect();
    let allocs: Vec<Json> = report
        .allocs
        .iter()
        .map(|a| {
            Json::obj(vec![
                ("lane", Json::Str(a.lane.clone())),
                ("allocs_per_infer", Json::Num(a.allocs_per_infer)),
                ("alloc_bytes_per_infer", Json::Num(a.alloc_bytes_per_infer)),
            ])
        })
        .collect();
    let j = Json::obj(vec![
        ("bench", Json::Str("native engine: scalar vs simd vs int8 inference".into())),
        ("fast", Json::Num(if report.fast { 1.0 } else { 0.0 })),
        ("kernel_variant", Json::Str(report.variant.clone())),
        ("results", Json::Arr(rows)),
        ("speedups", Json::Arr(speedups)),
        ("allocs", Json::Arr(allocs)),
        ("max_rel_dev_simd", Json::Num(report.max_rel_dev_simd)),
        ("max_z_dev_int8", Json::Num(report.max_z_dev_int8)),
        ("int8_rank_agreement", Json::Num(report.int8_rank_agreement)),
        ("mape_f32", Json::Num(report.mape_f32)),
        ("mape_int8", Json::Num(report.mape_int8)),
    ]);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, j.to_string()).with_context(|| format!("write {}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_simd_bench_runs_and_gates_numerics() {
        // Structure + the unconditional numeric-mode gates only. The
        // wall-clock bars are enforced by the serial CI bench step
        // (`gcn-perf bench --fast --require-speedup`), not here —
        // `cargo test` shares cores with sibling tests.
        let report = run_simd_bench(&SimdBenchConfig { fast: true, seed: 5 }).unwrap();
        assert_eq!(report.rows.len(), 6);
        assert!(report.rows.iter().all(|r| r.mean_ns > 0.0 && r.graphs_per_s > 0.0));
        assert_eq!(report.speedups.len(), 4);
        for (name, x) in &report.speedups {
            assert!(x.is_finite() && *x > 0.0, "{name} ratio is {x}");
        }
        assert_eq!(report.allocs.len(), 3);
        assert!(report.max_rel_dev_simd <= SIMD_REL_TOL);
        assert!(report.int8_rank_agreement >= INT8_RANK_AGREEMENT_MIN);
        assert!(report.mape_f32.is_finite() && report.mape_int8.is_finite());
        assert!(report.speedup("padded/simd").is_finite());
        assert!(report.speedup("no-such-cell").is_nan());
        // in a default (no-simd) build the speed gates self-skip, so this
        // must pass everywhere; the simd CI lane exercises the real bars
        if report.variant == "scalar" {
            report.require_speedup().unwrap();
        }

        let path = std::env::temp_dir().join("gcn_perf_bench8_test.json");
        write_simd_report(&report, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.contains("scalar_over_lane"));
        assert!(text.contains("int8_rank_agreement"));
        crate::util::json::Json::parse(&text).unwrap();
        std::fs::remove_file(&path).ok();
    }
}
