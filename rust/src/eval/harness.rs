//! End-to-end experiment orchestration for the paper's figures — shared by
//! the `gcn-perf` CLI and the `examples/` binaries.
//!
//! The harnesses take `&dyn Predictor`, and
//! [`crate::predictor::PredictService`] *is* a predictor — the CLI passes
//! a service around the loaded bundle, so harness traffic rides the
//! coalescing serving layer (and shares its cache with any concurrent
//! clients) without the harness knowing.

use crate::baselines::gbt::GbtConfig;
use crate::baselines::halide_ffn::FfnTrainConfig;
use crate::dataset::builder::sample_from_schedule;
use crate::dataset::sample::Dataset;
use crate::eval::metrics::{regression_metrics, RegressionMetrics};
use crate::eval::ranking::{pairwise_ranking_accuracy, RankResult};
use crate::lower::lower_pipeline;
use crate::predictor::{FfnPredictor, GbtPredictor, GruPredictor, Predictor};
use crate::schedule::primitives::PipelineSchedule;
use crate::schedule::random::random_pipeline_schedule;
use crate::sim::Machine;
use crate::util::json::Json;
use crate::util::rng::Rng;
use anyhow::Result;

/// Fig 8: evaluate the trained GCN (any [`Predictor`] — usually a
/// `GcnPredictor` session or a training-loop `GcnView`) plus freshly
/// fitted baselines on the test split.
pub fn run_fig8(
    gcn: &dyn Predictor,
    train_ds: &Dataset,
    test_ds: &Dataset,
    ffn_epochs: usize,
    verbose: bool,
) -> Result<Vec<RegressionMetrics>> {
    let truth: Vec<f64> = test_ds.samples.iter().map(|s| s.mean_runtime()).collect();

    // ours (the GCN session)
    let refs: Vec<&crate::dataset::sample::GraphSample> = test_ds.samples.iter().collect();
    let gcn_pred = gcn.predict(&refs)?;
    let mut rows = vec![regression_metrics(&format!("{} (ours)", gcn.name()), &truth, &gcn_pred)];

    // Halide FFN baseline — trained on the same train split (§IV-A: "we
    // train and evaluate it on our train and test set")
    if verbose {
        eprintln!("fitting halide-ffn baseline ({ffn_epochs} epochs)...");
    }
    let ffn = FfnPredictor::fit(
        train_ds,
        &FfnTrainConfig { epochs: ffn_epochs, ..Default::default() },
        99,
    )?;
    let ffn_pred = ffn.predict(&refs)?;
    rows.push(regression_metrics(&ffn.name(), &truth, &ffn_pred));

    // TVM GBT baseline — "Since it does not require any pre-training, we
    // used the test split of our dataset on this XGBoost based model": the
    // TVM model trains online on measurements of the workload it tunes. We
    // emulate that protocol with a within-test-split fit on half the
    // schedules of each pipeline, predicting the other half.
    if verbose {
        eprintln!("fitting tvm-gbt baseline (online protocol)...");
    }
    let (gbt_truth, gbt_pred) = gbt_online_eval(test_ds)?;
    rows.push(regression_metrics("tvm-gbt", &gbt_truth, &gbt_pred));

    Ok(rows)
}

/// Extension row beyond the paper's Fig 8: the recurrent (bi-GRU) baseline
/// standing in for the Halide value-learning LSTM model [6] — sequence
/// order without DAG structure.
pub fn run_fig8_rnn(
    train_ds: &Dataset,
    test_ds: &Dataset,
    epochs: usize,
    verbose: bool,
) -> Result<RegressionMetrics> {
    use crate::baselines::rnn::RnnTrainConfig;
    if verbose {
        eprintln!("fitting bi-gru baseline ({epochs} epochs)...");
    }
    let gru =
        GruPredictor::fit(train_ds, &RnnTrainConfig { epochs, ..Default::default() }, 64, 41)?;
    let truth: Vec<f64> = test_ds.samples.iter().map(|s| s.mean_runtime()).collect();
    let refs: Vec<&crate::dataset::sample::GraphSample> = test_ds.samples.iter().collect();
    let pred = gru.predict(&refs)?;
    Ok(regression_metrics("bi-gru (ext)", &truth, &pred))
}

/// TVM online protocol: per the paper, the GBT model sees measurements from
/// the same pipelines it predicts (its exploration phase). Fit on the even
/// schedule ids of the test split, evaluate on the odd ones.
pub fn gbt_online_eval(test_ds: &Dataset) -> Result<(Vec<f64>, Vec<f64>)> {
    let mut fit = Dataset::default();
    let mut eval = Dataset::default();
    for s in &test_ds.samples {
        if s.schedule_id % 2 == 0 {
            fit.samples.push(s.clone());
        } else {
            eval.samples.push(s.clone());
        }
    }
    let gbt = GbtPredictor::fit(&fit, GbtConfig::default());
    let truth: Vec<f64> = eval.samples.iter().map(|s| s.mean_runtime()).collect();
    let refs: Vec<&crate::dataset::sample::GraphSample> = eval.samples.iter().collect();
    let pred = gbt.predict(&refs)?;
    Ok((truth, pred))
}

/// Fig 9: pairwise ranking on the zoo networks — the paper's nine plus
/// the >48-stage resnet50 the sparse batching unlocked. `n_schedules`
/// per network ("several hundred schedules" in the paper; configurable
/// here). The predictor is self-contained (a bundle-loaded session
/// carries its own feature stats), so this needs no dataset.
pub fn run_fig9(
    p: &dyn Predictor,
    machine: &Machine,
    n_schedules: usize,
    seed: u64,
) -> Result<Vec<RankResult>> {
    let mut results = Vec::new();
    for net in crate::zoo::all_networks() {
        let nests = lower_pipeline(&net);
        let ranks: Vec<usize> = net.stages.iter().map(|s| s.shape.len()).collect();
        let mut rng = Rng::new(seed ^ net.name.len() as u64);

        let mut samples = Vec::with_capacity(n_schedules);
        for sid in 0..n_schedules {
            let sched = if sid == 0 {
                PipelineSchedule::default_for(&ranks)
            } else {
                random_pipeline_schedule(&net, &nests, &mut rng)
            };
            samples.push(sample_from_schedule(
                &net, &nests, &sched, machine, 0, sid as u32, &mut rng,
            ));
        }
        let truth: Vec<f64> = samples.iter().map(|s| s.mean_runtime()).collect();
        let refs: Vec<&crate::dataset::sample::GraphSample> = samples.iter().collect();
        let pred = p.predict(&refs)?;
        results.push(pairwise_ranking_accuracy(&net.name, &truth, &pred, 0.02));
    }
    Ok(results)
}

/// Serialize fig8 rows + fig9 results to a JSON report file.
pub fn write_report(
    path: &std::path::Path,
    fig8: &[RegressionMetrics],
    fig9: &[RankResult],
    fig9_avg: f64,
) -> Result<()> {
    let fig8_json: Vec<Json> = fig8
        .iter()
        .map(|m| {
            Json::obj(vec![
                ("model", Json::Str(m.model.clone())),
                ("avg_error_pct", Json::Num(m.avg_error_pct)),
                ("max_error_pct", Json::Num(m.max_error_pct)),
                ("r2", Json::Num(m.r2)),
                ("n", Json::Num(m.n as f64)),
            ])
        })
        .collect();
    let fig9_json: Vec<Json> = fig9
        .iter()
        .map(|r| {
            Json::obj(vec![
                ("network", Json::Str(r.network.clone())),
                ("n_schedules", Json::Num(r.n_schedules as f64)),
                ("n_pairs", Json::Num(r.n_pairs as f64)),
                ("accuracy_pct", Json::Num(r.accuracy_pct())),
            ])
        })
        .collect();
    let report = Json::obj(vec![
        ("fig8", Json::Arr(fig8_json)),
        ("fig9", Json::Arr(fig9_json)),
        ("fig9_avg_pct", Json::Num(fig9_avg)),
    ]);
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir)?;
    }
    std::fs::write(path, report.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::dataset::builder::{build_dataset, DataGenConfig};

    #[test]
    fn gbt_online_eval_splits_by_schedule_parity() {
        let ds = build_dataset(&DataGenConfig {
            n_pipelines: 6,
            schedules_per_pipeline: 8,
            seed: 77,
            ..Default::default()
        });
        let (truth, pred) = gbt_online_eval(&ds).unwrap();
        assert_eq!(truth.len(), 6 * 4); // odd schedule ids
        assert_eq!(truth.len(), pred.len());
        assert!(pred.iter().all(|p| p.is_finite() && *p > 0.0));
    }
}
