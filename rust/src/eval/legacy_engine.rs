//! Frozen snapshot of the **PR-4 native engine's compute core** — the
//! baseline `gcn-perf bench --engine` (`BENCH_5.json`) measures the
//! PR-5 engine against, and the anchor for the fast-path parity check
//! inside the bench run.
//!
//! Characteristics deliberately preserved from PR 4 (do **not** optimize
//! this module — its whole value is staying slow in exactly the old ways):
//!
//! * every forward allocates all of its buffers fresh, and the parallel
//!   row fill allocates per-block `Vec`s and then re-copies them into a
//!   joined output;
//! * inference materializes the full training stash (`e`/`h`/`xhat`/
//!   `rstd`) it never reads;
//! * the embedding GEMM is output-outer (strided weight reads), untiled;
//! * `backward` is a single sequential pass over the packed nodes.
//!
//! Semantically it is the same model, so its outputs are bit-identical
//! to the PR-5 engine's (the bench asserts this before timing anything).

use crate::constants::{DEP_DIM, EMB_DEP, EMB_INV, INV_DIM, NODE_DIM, N_CONV};
use crate::model::PackedBatch;
use crate::runtime::native::{apply_adagrad, loss_and_dz, LN_EPS};
use crate::runtime::params::Params;
use crate::runtime::Manifest;
use crate::util::threadpool::{chunk_ranges, parallel_map};
use std::ops::Range;

/// PR-4 parallel-block threshold (same value the old engine used).
const PAR_MIN_ROWS: usize = 512;

/// PR-4 row fill: per-block `Vec` allocations joined by `extend_from_slice`.
fn par_rows<F>(n_rows: usize, width: usize, f: F) -> Vec<f32>
where
    F: Fn(usize, &mut [f32]) + Sync,
{
    let ranges = chunk_ranges(n_rows, PAR_MIN_ROWS);
    if ranges.len() <= 1 {
        let mut out = vec![0f32; n_rows * width];
        for (r, row) in out.chunks_mut(width.max(1)).enumerate() {
            f(r, row);
        }
        return out;
    }
    let parts = parallel_map(&ranges, |range| {
        let mut block = vec![0f32; range.len() * width];
        for (i, row) in block.chunks_mut(width.max(1)).enumerate() {
            f(range.start + i, row);
        }
        block
    });
    let mut out = Vec::with_capacity(n_rows * width);
    for p in parts {
        out.extend_from_slice(&p);
    }
    out
}

struct ConvRows {
    h: Vec<f32>,
    xhat: Vec<f32>,
    e_next: Vec<f32>,
    rstd: Vec<f32>,
}

fn conv_block(
    batch: &PackedBatch,
    t: &[f32],
    bvec: &[f32],
    scale: &[f32],
    shift: &[f32],
    range: Range<usize>,
) -> ConvRows {
    let n = range.len();
    let mut out = ConvRows {
        h: vec![0f32; n * NODE_DIM],
        xhat: vec![0f32; n * NODE_DIM],
        e_next: vec![0f32; n * NODE_DIM],
        rstd: vec![0f32; n],
    };
    for (i, node) in range.enumerate() {
        let (cols, vals) = batch.adj.row(node);
        let mut c = [0f64; NODE_DIM];
        for (&cix, &a) in cols.iter().zip(vals) {
            let af = a as f64;
            let t_row = &t[cix as usize * NODE_DIM..(cix as usize + 1) * NODE_DIM];
            for j in 0..NODE_DIM {
                c[j] += af * t_row[j] as f64;
            }
        }
        for j in 0..NODE_DIM {
            c[j] += bvec[j] as f64;
        }
        let mean = c.iter().sum::<f64>() / NODE_DIM as f64;
        let var = c.iter().map(|&x| (x - mean) * (x - mean)).sum::<f64>() / NODE_DIM as f64;
        let rs = 1.0 / (var + LN_EPS).sqrt();
        out.rstd[i] = rs as f32;
        let o = i * NODE_DIM;
        for j in 0..NODE_DIM {
            let xh = (c[j] - mean) * rs;
            out.xhat[o + j] = xh as f32;
            let hv = xh * scale[j] as f64 + shift[j] as f64;
            out.h[o + j] = hv as f32;
            out.e_next[o + j] = hv.max(0.0) as f32;
        }
    }
    out
}

fn par_conv(
    batch: &PackedBatch,
    t: &[f32],
    bvec: &[f32],
    scale: &[f32],
    shift: &[f32],
) -> ConvRows {
    let nn = batch.total_nodes();
    let ranges = chunk_ranges(nn, PAR_MIN_ROWS);
    if ranges.len() <= 1 {
        return conv_block(batch, t, bvec, scale, shift, 0..nn);
    }
    let parts = parallel_map(&ranges, |r| conv_block(batch, t, bvec, scale, shift, r.clone()));
    let mut out = ConvRows {
        h: Vec::with_capacity(nn * NODE_DIM),
        xhat: Vec::with_capacity(nn * NODE_DIM),
        e_next: Vec::with_capacity(nn * NODE_DIM),
        rstd: Vec::with_capacity(nn),
    };
    for p in parts {
        out.h.extend_from_slice(&p.h);
        out.xhat.extend_from_slice(&p.xhat);
        out.e_next.extend_from_slice(&p.e_next);
        out.rstd.extend_from_slice(&p.rstd);
    }
    out
}

struct Forward {
    e: Vec<Vec<f32>>,
    h: Vec<Vec<f32>>,
    xhat: Vec<Vec<f32>>,
    rstd: Vec<Vec<f32>>,
    feat: Vec<f32>,
    z: Vec<f32>,
}

/// The PR-4 engine: same model, yesterday's loops.
pub(crate) struct LegacyEngine {
    manifest: Manifest,
}

impl LegacyEngine {
    pub(crate) fn new() -> LegacyEngine {
        LegacyEngine { manifest: Manifest::native(N_CONV) }
    }

    fn n_conv(&self) -> usize {
        self.manifest.n_conv
    }

    fn readout(&self) -> usize {
        NODE_DIM * (self.n_conv() + 1)
    }

    fn p_w_out(&self) -> usize {
        4 + 4 * self.n_conv()
    }

    fn forward(&self, params: &Params, batch: &PackedBatch) -> Forward {
        let kk = self.n_conv();
        let readout = self.readout();
        let nn = batch.total_nodes();
        let nb = batch.n_graphs();

        // PR-4 embedding: output-outer, strided weight reads
        let (w_inv, b_inv) = (&params.values[0], &params.values[1]);
        let (w_dep, b_dep) = (&params.values[2], &params.values[3]);
        let e0 = par_rows(nn, NODE_DIM, |node, out| {
            let inv = &batch.inv[node * INV_DIM..(node + 1) * INV_DIM];
            let dep = &batch.dep[node * DEP_DIM..(node + 1) * DEP_DIM];
            for j in 0..EMB_INV {
                let mut acc = b_inv[j] as f64;
                for (i, &x) in inv.iter().enumerate() {
                    acc += x as f64 * w_inv[i * EMB_INV + j] as f64;
                }
                out[j] = acc.max(0.0) as f32;
            }
            for j in 0..EMB_DEP {
                let mut acc = b_dep[j] as f64;
                for (i, &x) in dep.iter().enumerate() {
                    acc += x as f64 * w_dep[i * EMB_DEP + j] as f64;
                }
                out[EMB_INV + j] = acc.max(0.0) as f32;
            }
        });

        let mut e_list = Vec::with_capacity(kk + 1);
        e_list.push(e0);
        let mut h_list = Vec::with_capacity(kk);
        let mut xhat_list = Vec::with_capacity(kk);
        let mut rstd_list = Vec::with_capacity(kk);

        for k in 0..kk {
            let w = &params.values[4 + 4 * k];
            let bvec = &params.values[5 + 4 * k];
            let scale = &params.values[6 + 4 * k];
            let shift = &params.values[7 + 4 * k];
            let e_prev = &e_list[k];

            let t = par_rows(nn, NODE_DIM, |node, t_row| {
                let e_row = &e_prev[node * NODE_DIM..(node + 1) * NODE_DIM];
                let mut acc = [0f64; NODE_DIM];
                for (i, &x) in e_row.iter().enumerate() {
                    if x == 0.0 {
                        continue;
                    }
                    let xf = x as f64;
                    let wrow = &w[i * NODE_DIM..(i + 1) * NODE_DIM];
                    for j in 0..NODE_DIM {
                        acc[j] += xf * wrow[j] as f64;
                    }
                }
                for j in 0..NODE_DIM {
                    t_row[j] = acc[j] as f32;
                }
            });

            let conv = par_conv(batch, &t, bvec, scale, shift);
            h_list.push(conv.h);
            xhat_list.push(conv.xhat);
            rstd_list.push(conv.rstd);
            e_list.push(conv.e_next);
        }

        let w_out = &params.values[self.p_w_out()];
        let b_out = &params.values[self.p_w_out() + 1];
        let mut feat = vec![0f32; nb * readout];
        let mut z = vec![0f32; nb];
        for g in 0..nb {
            for (k, e) in e_list.iter().enumerate() {
                let f_off = g * readout + k * NODE_DIM;
                for node in batch.graph_nodes(g) {
                    let row = &e[node * NODE_DIM..(node + 1) * NODE_DIM];
                    for j in 0..NODE_DIM {
                        feat[f_off + j] += row[j];
                    }
                }
            }
            let mut acc = b_out[0] as f64;
            for r in 0..readout {
                acc += feat[g * readout + r] as f64 * w_out[r] as f64;
            }
            z[g] = acc as f32;
        }

        Forward { e: e_list, h: h_list, xhat: xhat_list, rstd: rstd_list, feat, z }
    }

    /// PR-4 inference: the full training forward, keeping every
    /// intermediate it will never read.
    pub(crate) fn infer(&self, params: &Params, batch: &PackedBatch) -> Vec<f32> {
        self.forward(params, batch).z
    }

    /// PR-4 backward: one sequential pass over the packed nodes.
    fn backward(
        &self,
        params: &Params,
        batch: &PackedBatch,
        fwd: &Forward,
        dz: &[f64],
    ) -> Vec<Vec<f64>> {
        let kk = self.n_conv();
        let readout = self.readout();
        let iw = self.p_w_out();
        let w_out = &params.values[iw];
        let nn = batch.total_nodes();
        let nb = batch.n_graphs();
        let mut grads: Vec<Vec<f64>> =
            params.values.iter().map(|v| vec![0f64; v.len()]).collect();

        for g in 0..nb {
            if dz[g] == 0.0 {
                continue;
            }
            grads[iw + 1][0] += dz[g];
            for r in 0..readout {
                grads[iw][r] += fwd.feat[g * readout + r] as f64 * dz[g];
            }
        }

        let mut de = vec![0f64; nn * NODE_DIM];
        for g in 0..nb {
            if dz[g] == 0.0 {
                continue;
            }
            for node in batch.graph_nodes(g) {
                let o = node * NODE_DIM;
                for j in 0..NODE_DIM {
                    de[o + j] = dz[g] * w_out[kk * NODE_DIM + j] as f64;
                }
            }
        }

        for k in (0..kk).rev() {
            let w = &params.values[4 + 4 * k];
            let scale = &params.values[6 + 4 * k];
            let h = &fwd.h[k];
            let xh = &fwd.xhat[k];
            let rstd = &fwd.rstd[k];
            let e_prev = &fwd.e[k];

            let mut dc = vec![0f64; nn * NODE_DIM];
            for node in 0..nn {
                let o = node * NODE_DIM;
                let mut dxh = [0f64; NODE_DIM];
                let mut sum1 = 0f64;
                let mut sum2 = 0f64;
                for j in 0..NODE_DIM {
                    let dh = if h[o + j] > 0.0 { de[o + j] } else { 0.0 };
                    grads[6 + 4 * k][j] += dh * xh[o + j] as f64;
                    grads[7 + 4 * k][j] += dh;
                    let dx = dh * scale[j] as f64;
                    dxh[j] = dx;
                    sum1 += dx;
                    sum2 += dx * xh[o + j] as f64;
                }
                let rs = rstd[node] as f64;
                for j in 0..NODE_DIM {
                    let v =
                        rs * (dxh[j] - (sum1 + xh[o + j] as f64 * sum2) / NODE_DIM as f64);
                    dc[o + j] = v;
                    grads[5 + 4 * k][j] += v;
                }
            }

            let adj_t = batch.adj_t();
            let mut dt = vec![0f64; nn * NODE_DIM];
            for node in 0..nn {
                let (rows, vals) = adj_t.row(node);
                let o = node * NODE_DIM;
                for (&r, &a) in rows.iter().zip(vals) {
                    let af = a as f64;
                    let src = &dc[r as usize * NODE_DIM..(r as usize + 1) * NODE_DIM];
                    for j in 0..NODE_DIM {
                        dt[o + j] += af * src[j];
                    }
                }
            }

            let mut de_new = vec![0f64; nn * NODE_DIM];
            for node in 0..nn {
                let o = node * NODE_DIM;
                let dtrow = &dt[o..o + NODE_DIM];
                let erow = &e_prev[o..o + NODE_DIM];
                for i in 0..NODE_DIM {
                    let wrow = &w[i * NODE_DIM..(i + 1) * NODE_DIM];
                    let mut acc = 0f64;
                    for j in 0..NODE_DIM {
                        acc += dtrow[j] * wrow[j] as f64;
                    }
                    de_new[o + i] = acc;
                    let ev = erow[i] as f64;
                    if ev != 0.0 {
                        let gw = &mut grads[4 + 4 * k][i * NODE_DIM..(i + 1) * NODE_DIM];
                        for j in 0..NODE_DIM {
                            gw[j] += ev * dtrow[j];
                        }
                    }
                }
            }

            for g in 0..nb {
                if dz[g] == 0.0 {
                    continue;
                }
                for node in batch.graph_nodes(g) {
                    let o = node * NODE_DIM;
                    for j in 0..NODE_DIM {
                        de_new[o + j] += dz[g] * w_out[k * NODE_DIM + j] as f64;
                    }
                }
            }
            de = de_new;
        }

        let e0 = &fwd.e[0];
        for node in 0..nn {
            let o = node * NODE_DIM;
            let inv = &batch.inv[node * INV_DIM..(node + 1) * INV_DIM];
            let dep = &batch.dep[node * DEP_DIM..(node + 1) * DEP_DIM];
            for j in 0..EMB_INV {
                if e0[o + j] <= 0.0 {
                    continue;
                }
                let g = de[o + j];
                if g == 0.0 {
                    continue;
                }
                grads[1][j] += g;
                for (i, &x) in inv.iter().enumerate() {
                    grads[0][i * EMB_INV + j] += x as f64 * g;
                }
            }
            for j in 0..EMB_DEP {
                if e0[o + EMB_INV + j] <= 0.0 {
                    continue;
                }
                let g = de[o + EMB_INV + j];
                if g == 0.0 {
                    continue;
                }
                grads[3][j] += g;
                for (i, &x) in dep.iter().enumerate() {
                    grads[2][i * EMB_DEP + j] += x as f64 * g;
                }
            }
        }

        grads
    }

    /// PR-4 train step: full forward, sequential backward, Adagrad.
    pub(crate) fn train_step_lr(
        &self,
        params: &mut Params,
        accum: &mut Params,
        batch: &PackedBatch,
        lr: f32,
    ) -> f32 {
        let fwd = self.forward(params, batch);
        let (loss, dz) = loss_and_dz(&fwd.z, batch);
        let grads = self.backward(params, batch, &fwd, &dz);
        apply_adagrad(params, accum, &grads, lr as f64, self.manifest.weight_decay);
        loss as f32
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::{Backend, NativeBackend};
    use crate::testfix::synth_packed_batch;

    #[test]
    fn legacy_engine_matches_current_engine() {
        // the baseline must stay semantically identical to the current
        // engine, or BENCH_5's speedups would compare different models
        let legacy = LegacyEngine::new();
        let current = NativeBackend::new();
        let batch = synth_packed_batch();
        let params = current.init_params(21);
        let z_legacy = legacy.infer(&params, &batch);
        let z_current = current.infer(&params, &batch).unwrap();
        assert_eq!(z_legacy, z_current, "legacy and current engines diverge on inference");

        let mut pl = params.clone();
        let mut al = pl.zeros_like();
        let mut pc = params.clone();
        let mut ac = pc.zeros_like();
        let ll = legacy.train_step_lr(&mut pl, &mut al, &batch, 0.01);
        let lc = current.train_step_lr(&mut pc, &mut ac, &batch, 0.01).unwrap();
        assert!((ll - lc).abs() <= 1e-6 * lc.abs().max(1.0), "loss diverges: {ll} vs {lc}");
        for (t, (vl, vc)) in pl.values.iter().zip(&pc.values).enumerate() {
            for (i, (a, b)) in vl.iter().zip(vc).enumerate() {
                assert!(
                    (a - b).abs() <= 1e-6,
                    "post-step param[{t}][{i}] diverges: {a} vs {b}"
                );
            }
        }
    }
}
