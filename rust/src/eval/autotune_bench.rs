//! Autotuner fleet benchmark — `BENCH_7.json`.
//!
//! Tunes the same set of zoo pipelines twice with identical seeds and a
//! GCN predictor: once sequentially (one pipeline at a time through a
//! private-use service) and once as the concurrent fleet, every search
//! worker sharing one [`PredictService`]. Before any number is reported
//! the two runs are asserted **bitwise identical** per pipeline — same
//! best schedule, same tuned cost — which is the fleet's core claim:
//! concurrency (and the coalescer fusing frontiers from different
//! searches) changes wall-clock, never results. The report carries both
//! wall times, the concurrent/sequential speedup, tuned-vs-default cost
//! per pipeline, and both services' counters (cache hits, fused batches,
//! queue saturation).
//!
//! CI runs the `--fast` variant via `gcn-perf bench --fast
//! --autotune-out ...`; the `--require-speedup` gate (fleet beats
//! sequential, tuned never worse than default) is enforced by that
//! serial CI step, not by `cargo test`, which shares cores.

use crate::autotune::{run_fleet, EvolutionConfig, FleetConfig, FleetCost, FleetReport};
use crate::dataset::builder::{build_dataset, DataGenConfig};
use crate::predictor::{GcnPredictor, PredictService, Predictor, ServiceConfig};
use crate::runtime::{Backend, NativeBackend};
use crate::util::json::Json;
use crate::util::threadpool;
use anyhow::{ensure, Context, Result};
use std::path::Path;
use std::sync::Arc;

#[derive(Debug, Clone)]
pub struct AutotuneBenchConfig {
    /// Short run (CI smoke).
    pub fast: bool,
    pub seed: u64,
}

impl Default for AutotuneBenchConfig {
    fn default() -> Self {
        AutotuneBenchConfig { fast: false, seed: 7 }
    }
}

/// Both runs' outcomes plus the shared workload description.
pub struct AutotuneBenchReport {
    pub fast: bool,
    pub networks: Vec<String>,
    pub sequential: FleetReport,
    pub concurrent: FleetReport,
    /// The fleet configs the runs used (for the report JSON).
    pub seq_cfg: FleetConfig,
    pub conc_cfg: FleetConfig,
}

impl AutotuneBenchReport {
    /// Concurrent-fleet speedup over sequential tuning (wall-clock).
    pub fn speedup(&self) -> f64 {
        self.sequential.wall_s / self.concurrent.wall_s
    }

    /// Error unless the fleet beat sequential tuning and no pipeline
    /// regressed past its default schedule (the `--require-speedup`
    /// gate).
    pub fn require_speedup(&self) -> Result<()> {
        ensure!(
            self.speedup() > 1.0,
            "concurrent fleet ({:.2}s) did not beat sequential tuning ({:.2}s)",
            self.concurrent.wall_s,
            self.sequential.wall_s
        );
        for r in &self.concurrent.results {
            ensure!(
                r.tuned_cost <= r.default_cost,
                "{}: tuned cost {} worse than default {}",
                r.network,
                r.tuned_cost,
                r.default_cost
            );
        }
        Ok(())
    }
}

/// A small GCN predictor bootstrapped the `net_bench` way: a generated
/// dataset for feature stats, fresh native-engine parameters. Model
/// quality is irrelevant here (the incumbent rule guards results); what
/// matters is real featurize → coalesce → GCN-forward serving load.
fn build_predictor(seed: u64) -> Result<Arc<dyn Predictor>> {
    let ds = build_dataset(&DataGenConfig {
        n_pipelines: 8,
        schedules_per_pipeline: 4,
        seed,
        ..Default::default()
    });
    let stats = ds.stats.clone().context("dataset stats")?;
    let backend = NativeBackend::new();
    let params = backend.init_params(seed);
    Ok(Arc::new(GcnPredictor::new(Box::new(backend), params, stats)))
}

fn fleet_config(cfg: &AutotuneBenchConfig, sequential: bool) -> FleetConfig {
    let networks: Vec<String> = if cfg.fast {
        vec!["alexnet".into(), "squeezenet".into(), "unet".into(), "resnet18".into()]
    } else {
        vec![
            "alexnet".into(),
            "squeezenet".into(),
            "unet".into(),
            "resnet18".into(),
            "mobilenet_v2".into(),
            "shufflenet".into(),
        ]
    };
    let evolution = if cfg.fast {
        EvolutionConfig { population: 3, offspring: 6, immigrants: 2, generations: 3, seed: 0 }
    } else {
        EvolutionConfig { generations: 8, ..Default::default() }
    };
    FleetConfig { networks, evolution, seed: cfg.seed, sequential, ..Default::default() }
}

fn spawn_service(predictor: &Arc<dyn Predictor>, n_pipelines: usize) -> Arc<PredictService> {
    Arc::new(PredictService::spawn(
        Arc::clone(predictor),
        ServiceConfig {
            workers: threadpool::num_threads().clamp(1, 4),
            queue_cap: (2 * n_pipelines).max(8),
            ..Default::default()
        },
    ))
}

/// Run both modes and cross-check them bitwise.
pub fn run_autotune_bench(cfg: &AutotuneBenchConfig) -> Result<AutotuneBenchReport> {
    let predictor = build_predictor(cfg.seed)?;

    let seq_cfg = fleet_config(cfg, true);
    let seq_service = spawn_service(&predictor, seq_cfg.networks.len());
    let mut sequential = run_fleet(&seq_cfg, &FleetCost::Service(seq_service))?;

    let conc_cfg = fleet_config(cfg, false);
    let conc_service = spawn_service(&predictor, conc_cfg.networks.len());
    let concurrent = run_fleet(&conc_cfg, &FleetCost::Service(conc_service))?;

    // results must be mode-independent before timings mean anything
    for (a, b) in sequential.results.iter().zip(&concurrent.results) {
        ensure!(a.network == b.network, "result order diverged: {} vs {}", a.network, b.network);
        ensure!(
            a.tuned_cost.to_bits() == b.tuned_cost.to_bits()
                && a.best_schedule == b.best_schedule,
            "{}: sequential and concurrent tuning disagree ({} vs {})",
            a.network,
            a.tuned_cost,
            b.tuned_cost
        );
    }
    // traces are labeled from the same scored candidates either way
    ensure!(
        sequential.samples.len() == concurrent.samples.len(),
        "trace sizes diverged: {} vs {}",
        sequential.samples.len(),
        concurrent.samples.len()
    );
    sequential.samples.clear(); // keep one copy; the runs agree

    Ok(AutotuneBenchReport {
        fast: cfg.fast,
        networks: conc_cfg.networks.clone(),
        sequential,
        concurrent,
        seq_cfg,
        conc_cfg,
    })
}

/// Serialize a report to `BENCH_7.json`.
pub fn write_autotune_report(report: &AutotuneBenchReport, path: &Path) -> Result<()> {
    let j = Json::obj(vec![
        (
            "bench",
            Json::Str("autotune: concurrent fleet vs sequential tuning, shared service".into()),
        ),
        ("fast", Json::Num(if report.fast { 1.0 } else { 0.0 })),
        ("networks", Json::Arr(report.networks.iter().map(|n| Json::Str(n.clone())).collect())),
        ("sequential", report.sequential.to_json(&report.seq_cfg)),
        ("concurrent", report.concurrent.to_json(&report.conc_cfg)),
        ("speedup", Json::Num(report.speedup())),
    ]);
    if let Some(dir) = path.parent() {
        if !dir.as_os_str().is_empty() {
            std::fs::create_dir_all(dir)?;
        }
    }
    std::fs::write(path, j.to_string())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fast_autotune_bench_agrees_across_modes_and_reports() {
        // Structure + the built-in bitwise cross-check. The speedup gate
        // is enforced by the serial CI step (`bench --require-speedup`),
        // not here — `cargo test` shares cores.
        let report = run_autotune_bench(&AutotuneBenchConfig { fast: true, seed: 13 }).unwrap();
        assert_eq!(report.networks.len(), 4);
        assert_eq!(report.concurrent.results.len(), 4);
        for r in &report.concurrent.results {
            assert!(r.completed);
            assert!(r.tuned_cost <= r.default_cost, "{}: incumbent rule violated", r.network);
        }
        let svc = report.concurrent.service_stats.as_ref().expect("shared service counters");
        assert!(svc.requests > 0 && svc.samples_evaluated > 0);
        assert!(!report.concurrent.samples.is_empty(), "harvested traces");

        let path = std::env::temp_dir().join("gcn_perf_bench7_test.json");
        write_autotune_report(&report, &path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        for key in ["sequential", "concurrent", "speedup", "tuned_cost", "cache_hits"] {
            assert!(text.contains(key), "missing {key}");
        }
        Json::parse(&text).unwrap();
        std::fs::remove_file(&path).ok();
    }
}
