//! Schedule data model.

/// Where a stage's computation happens relative to its consumers (§II-A.1).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ComputeLoc {
    /// `compute_root()`: materialize the whole buffer before consumers run.
    Root,
    /// `compute_at(consumer, level)`: compute per consumer tile; `level` is
    /// the consumer loop depth (0 = outermost) the producer nests under.
    At { consumer: usize, level: usize },
    /// Inline the expression into every use (Halide's default for pure
    /// `Func`s): no buffer, possible recompute.
    Inline,
}

/// Scheduling decisions for one stage.
///
/// Loops are identified by their spatial dimension index (0 = outermost
/// output dim). `tile[d]` is the split factor of dim `d` (1 = unsplit); a
/// split produces `d_outer` with extent `ceil(extent/f)` and `d_inner` with
/// extent `f`, and the tiled order is all outers (in `order`) followed by
/// all inners (in `order`) followed by reduction loops — the classic
/// tiled/blocked execution of §II-A.3.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct StageSchedule {
    /// Permutation of spatial dims, outermost-first traversal order.
    pub order: Vec<usize>,
    /// Split factor per spatial dim (aligned to dim index, not order).
    pub tile: Vec<usize>,
    /// SIMD width applied to the innermost loop (1 = scalar; 4/8 = vector).
    pub vector_width: usize,
    /// Number of outermost loops (in `order`) annotated `parallel`.
    pub parallel_depth: usize,
    /// Unroll factor of the innermost loop (1 = none).
    pub unroll: usize,
    pub compute: ComputeLoc,
}

impl StageSchedule {
    /// The Halide default: compute_root, natural order, no tiling, scalar.
    pub fn default_for(rank: usize) -> StageSchedule {
        StageSchedule {
            order: (0..rank).collect(),
            tile: vec![1; rank],
            vector_width: 1,
            parallel_depth: 0,
            unroll: 1,
            compute: ComputeLoc::Root,
        }
    }

    /// Innermost spatial dim after reordering.
    pub fn innermost_dim(&self) -> Option<usize> {
        self.order.last().copied()
    }

    /// True if any dim is split.
    pub fn is_tiled(&self) -> bool {
        self.tile.iter().any(|&f| f > 1)
    }

    /// Extents of the loop nest after applying order+tiling to `spatial`,
    /// outermost-first: [outer loops.., inner loops..]. Inner loops appear
    /// only for split dims.
    pub fn loop_extents(&self, spatial: &[usize]) -> Vec<usize> {
        let mut outer = Vec::new();
        let mut inner = Vec::new();
        for &d in &self.order {
            let extent = spatial[d];
            let f = self.tile[d].max(1);
            if f > 1 && f < extent {
                outer.push(extent.div_ceil(f));
                inner.push(f);
            } else {
                outer.push(extent);
            }
        }
        outer.extend(inner);
        outer
    }

    /// Number of parallel tasks this schedule exposes (product of the
    /// extents of the `parallel_depth` outermost loops).
    pub fn parallel_tasks(&self, spatial: &[usize]) -> usize {
        let extents = self.loop_extents(spatial);
        extents.iter().take(self.parallel_depth).product::<usize>().max(1)
    }
}

/// One schedule per stage of a pipeline (index = stage id). All-integer
/// fields, so schedules are `Eq + Hash` — [`crate::predictor::PredictorCost`]
/// keys its memoization cache on complete schedules.
#[derive(Debug, Clone, PartialEq, Eq, Hash)]
pub struct PipelineSchedule {
    pub stages: Vec<StageSchedule>,
}

impl PipelineSchedule {
    pub fn default_for(ranks: &[usize]) -> PipelineSchedule {
        PipelineSchedule {
            stages: ranks.iter().map(|&r| StageSchedule::default_for(r)).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_schedule_shape() {
        let s = StageSchedule::default_for(3);
        assert_eq!(s.order, vec![0, 1, 2]);
        assert_eq!(s.tile, vec![1, 1, 1]);
        assert_eq!(s.compute, ComputeLoc::Root);
        assert!(!s.is_tiled());
    }

    #[test]
    fn loop_extents_with_split() {
        let mut s = StageSchedule::default_for(2);
        s.tile = vec![1, 8];
        // dims [16, 32], split dim1 by 8 -> loops [16, 4, 8]
        assert_eq!(s.loop_extents(&[16, 32]), vec![16, 4, 8]);
    }

    #[test]
    fn loop_extents_with_reorder_and_split() {
        let mut s = StageSchedule::default_for(2);
        s.order = vec![1, 0];
        s.tile = vec![4, 1];
        // order [d1, d0], d0 split by 4: outers [32, 4], inners [4]
        assert_eq!(s.loop_extents(&[16, 32]), vec![32, 4, 4]);
    }

    #[test]
    fn split_equal_or_larger_than_extent_is_noop() {
        let mut s = StageSchedule::default_for(1);
        s.tile = vec![64];
        assert_eq!(s.loop_extents(&[64]), vec![64]);
        s.tile = vec![128];
        assert_eq!(s.loop_extents(&[64]), vec![64]);
    }

    #[test]
    fn parallel_tasks_product_of_outer() {
        let mut s = StageSchedule::default_for(3);
        s.parallel_depth = 2;
        assert_eq!(s.parallel_tasks(&[4, 6, 100]), 24);
        s.parallel_depth = 0;
        assert_eq!(s.parallel_tasks(&[4, 6, 100]), 1);
    }

    #[test]
    fn nonuniform_split_rounds_up() {
        let mut s = StageSchedule::default_for(1);
        s.tile = vec![7];
        // 30 / 7 -> 5 outer iterations of 7 (last partial)
        assert_eq!(s.loop_extents(&[30]), vec![5, 7]);
    }
}
