//! Systematic schedule-space enumeration.
//!
//! The random sampler ([`super::random`]) mimics the paper's noisy
//! auto-scheduler; this module enumerates a *structured* candidate set per
//! stage (the way the Halide auto-scheduler's expansion step does) and, for
//! small pipelines, the exhaustive cross-product — used by the beam search
//! as a deterministic candidate generator and by tests as a ground-truth
//! optimum.

use crate::ir::pipeline::Pipeline;
use crate::lower::LoopNest;
use crate::schedule::legality::check_stage;
use crate::schedule::primitives::{ComputeLoc, PipelineSchedule, StageSchedule};
use crate::sim::{simulate, Machine};

/// Enumerate a bounded, legal candidate set for one stage.
///
/// Covers: natural + innermost-dim-swapped orders; untiled + one split per
/// trailing dim at factors {8, 32}; scalar/8-wide vectorization; 0/1
/// parallel depth; compute_root, inline (when legal) and compute_at each
/// consumer at level 2.
pub fn enumerate_stage(
    nest: &LoopNest,
    consumers: &[usize],
    all_scheds: &[StageSchedule],
) -> Vec<StageSchedule> {
    let rank = nest.spatial.len();
    let base = StageSchedule::default_for(rank);
    let mut out: Vec<StageSchedule> = Vec::new();

    // orders: natural, and (for rank>=2) swap of the two innermost dims
    let mut orders = vec![base.order.clone()];
    if rank >= 2 {
        let mut sw = base.order.clone();
        sw.swap(rank - 2, rank - 1);
        orders.push(sw);
    }

    // tilings: none, or split one of the last two dims by 8 / 32
    let mut tilings = vec![vec![1; rank]];
    for d in rank.saturating_sub(2)..rank {
        for f in [8usize, 32] {
            if nest.spatial[d] > f {
                let mut t = vec![1; rank];
                t[d] = f;
                tilings.push(t);
            }
        }
    }

    // compute locations
    let mut locs = vec![ComputeLoc::Root];
    if !consumers.is_empty() {
        if nest.pointwise && nest.reduction.is_empty() {
            locs.push(ComputeLoc::Inline);
        }
        for &c in consumers {
            locs.push(ComputeLoc::At { consumer: c, level: 2 });
        }
    }

    for order in &orders {
        for tile in &tilings {
            for vec_w in [1usize, 8] {
                for par in [0usize, 1] {
                    for &compute in &locs {
                        let mut s = base.clone();
                        s.order = order.clone();
                        s.tile = tile.clone();
                        s.vector_width = vec_w;
                        s.parallel_depth = par;
                        s.compute = compute;
                        if check_stage(nest, &s, consumers, all_scheds).is_ok() {
                            out.push(s);
                        }
                    }
                }
            }
        }
    }
    out.dedup();
    out
}

/// Exhaustive best schedule for a small pipeline (product of per-stage
/// candidate sets — only feasible for a few stages; asserts the search
/// space is below `limit`).
pub fn exhaustive_best(
    p: &Pipeline,
    nests: &[LoopNest],
    machine: &Machine,
    limit: usize,
) -> (PipelineSchedule, f64) {
    let consumers = p.consumers();
    let ranks: Vec<usize> = p.stages.iter().map(|s| s.shape.len()).collect();
    let defaults = PipelineSchedule::default_for(&ranks);
    let cand: Vec<Vec<StageSchedule>> = (0..p.num_stages())
        .map(|i| enumerate_stage(&nests[i], &consumers[i], &defaults.stages))
        .collect();
    let total: usize = cand.iter().map(|c| c.len()).product();
    assert!(
        total <= limit,
        "exhaustive space {total} exceeds limit {limit}"
    );

    let mut best = defaults.clone();
    let mut best_t = f64::INFINITY;
    let mut idx = vec![0usize; cand.len()];
    loop {
        let sched = PipelineSchedule {
            stages: idx.iter().enumerate().map(|(i, &j)| cand[i][j].clone()).collect(),
        };
        // cross-stage legality (compute_at inlined consumer) — skip illegal
        if crate::schedule::legality::check_pipeline(p, nests, &sched).is_ok() {
            let t = simulate(p, nests, &sched, machine);
            if t < best_t {
                best_t = t;
                best = sched;
            }
        }
        // odometer increment
        let mut k = 0;
        loop {
            idx[k] += 1;
            if idx[k] < cand[k].len() {
                break;
            }
            idx[k] = 0;
            k += 1;
            if k == idx.len() {
                return (best, best_t);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Op, OpAttrs, OpKind};
    use crate::lower::lower_pipeline;
    use crate::search::{beam_search, BeamConfig, SimCost};

    fn two_stage() -> (Pipeline, Vec<LoopNest>) {
        let mut p = Pipeline::new("t");
        let x = p.add_input(vec![1, 8, 32, 32]);
        let mut attrs = OpAttrs::default();
        attrs.out_channels = 16;
        let c = p.add_stage("conv", Op::with_attrs(OpKind::Conv2d, attrs), vec![x]).unwrap();
        p.add_stage("relu", Op::new(OpKind::Relu), vec![c]).unwrap();
        (p.clone(), lower_pipeline(&p))
    }

    #[test]
    fn enumeration_is_legal_and_nonempty() {
        let (p, nests) = two_stage();
        let consumers = p.consumers();
        let ranks: Vec<usize> = p.stages.iter().map(|s| s.shape.len()).collect();
        let defaults = PipelineSchedule::default_for(&ranks);
        for i in 0..p.num_stages() {
            let c = enumerate_stage(&nests[i], &consumers[i], &defaults.stages);
            assert!(c.len() >= 8, "stage {i}: only {} candidates", c.len());
            for s in &c {
                check_stage(&nests[i], s, &consumers[i], &defaults.stages).unwrap();
            }
        }
    }

    #[test]
    fn exhaustive_beats_default() {
        let (p, nests) = two_stage();
        let m = Machine::default();
        let ranks: Vec<usize> = p.stages.iter().map(|s| s.shape.len()).collect();
        let default_t = simulate(&p, &nests, &PipelineSchedule::default_for(&ranks), &m);
        let (_, best_t) = exhaustive_best(&p, &nests, &m, 1 << 22);
        assert!(best_t < default_t, "exhaustive {best_t} !< default {default_t}");
    }

    #[test]
    fn beam_with_oracle_close_to_exhaustive() {
        let (p, nests) = two_stage();
        let m = Machine::default();
        let (_, exact) = exhaustive_best(&p, &nests, &m, 1 << 22);
        let model = SimCost { machine: m.clone() };
        let (_, beam) = beam_search(
            &p,
            &nests,
            &model,
            &BeamConfig { beam_width: 8, candidates_per_stage: 24, seed: 4 },
        )
        .unwrap();
        // beam samples randomly, exhaustive enumerates structured options —
        // beam should land within 2x of the enumerated optimum
        assert!(
            beam <= exact * 2.0,
            "beam {beam} far from exhaustive {exact}"
        );
    }
}
