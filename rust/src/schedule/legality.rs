//! Schedule legality rules (the subset of Halide's constraints our IR
//! exposes). The random sampler and beam search only emit schedules that
//! pass [`check_pipeline`]; the simulator asserts it in debug builds.

use crate::ir::pipeline::Pipeline;
use crate::lower::LoopNest;
use crate::schedule::primitives::{ComputeLoc, PipelineSchedule, StageSchedule};

/// Validate one stage schedule against its loop nest.
pub fn check_stage(
    nest: &LoopNest,
    sched: &StageSchedule,
    consumers: &[usize],
    all_scheds: &[StageSchedule],
) -> Result<(), String> {
    let rank = nest.spatial.len();
    // order must be a permutation of 0..rank
    if sched.order.len() != rank {
        return Err(format!("order len {} != rank {}", sched.order.len(), rank));
    }
    let mut seen = vec![false; rank];
    for &d in &sched.order {
        if d >= rank || seen[d] {
            return Err(format!("order {:?} is not a permutation", sched.order));
        }
        seen[d] = true;
    }
    if sched.tile.len() != rank {
        return Err(format!("tile len {} != rank {}", sched.tile.len(), rank));
    }
    if sched.tile.iter().any(|&f| f == 0) {
        return Err("zero split factor".into());
    }
    // vectorization: innermost loop only, must be power of two 1/4/8, and
    // requires the innermost extent to cover the vector width
    match sched.vector_width {
        1 | 4 | 8 => {}
        w => return Err(format!("unsupported vector width {w}")),
    }
    if sched.vector_width > 1 {
        let inner = sched
            .innermost_dim()
            .ok_or_else(|| "vectorize on rank-0 stage".to_string())?;
        let extent = if sched.tile[inner] > 1 {
            sched.tile[inner]
        } else {
            nest.spatial[inner]
        };
        if extent < sched.vector_width {
            return Err(format!(
                "vector width {} exceeds innermost extent {}",
                sched.vector_width, extent
            ));
        }
    }
    match sched.unroll {
        1 | 2 | 4 | 8 => {}
        u => return Err(format!("unsupported unroll factor {u}")),
    }
    // parallel depth bounded by loop count
    let n_loops = sched.loop_extents(&nest.spatial).len();
    if sched.parallel_depth > n_loops.min(3) {
        return Err(format!(
            "parallel depth {} exceeds limit (loops={})",
            sched.parallel_depth, n_loops
        ));
    }
    // compute location rules
    match sched.compute {
        ComputeLoc::Root => {}
        ComputeLoc::Inline => {
            // Halide can only inline pure (no-reduction) single-valued funcs
            if !nest.pointwise || !nest.reduction.is_empty() {
                return Err("inline of non-pointwise stage".into());
            }
            if consumers.is_empty() {
                return Err("inline of an output stage".into());
            }
        }
        ComputeLoc::At { consumer, level } => {
            if !consumers.contains(&consumer) {
                return Err(format!("compute_at non-consumer {consumer}"));
            }
            // only legal when the consumer materializes (is not inlined)
            if consumer < all_scheds.len()
                && matches!(all_scheds[consumer].compute, ComputeLoc::Inline)
            {
                return Err("compute_at an inlined consumer".into());
            }
            if level == 0 || level > 3 {
                return Err(format!("compute_at level {level} out of range"));
            }
        }
    }
    Ok(())
}

/// Validate a whole pipeline schedule.
pub fn check_pipeline(
    p: &Pipeline,
    nests: &[LoopNest],
    sched: &PipelineSchedule,
) -> Result<(), String> {
    if sched.stages.len() != p.num_stages() {
        return Err(format!(
            "schedule covers {} stages, pipeline has {}",
            sched.stages.len(),
            p.num_stages()
        ));
    }
    let consumers = p.consumers();
    for (i, s) in sched.stages.iter().enumerate() {
        check_stage(&nests[i], s, &consumers[i], &sched.stages)
            .map_err(|e| format!("stage {i} ({}): {e}", p.stages[i].op.kind.name()))?;
    }
    // compute_at must not form chains deeper than the consumer's own nest
    // (we conservatively allow producer->consumer only when consumer is Root
    // or At — checked above — and forbid At cycles, impossible by topo order).
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Op, OpAttrs, OpKind};
    use crate::lower::lower_pipeline;

    fn two_stage() -> (Pipeline, Vec<LoopNest>) {
        let mut p = Pipeline::new("t");
        let x = p.add_input(vec![1, 16, 32, 32]);
        let mut attrs = OpAttrs::default();
        attrs.out_channels = 8;
        let c = p.add_stage("conv", Op::with_attrs(OpKind::Conv2d, attrs), vec![x]).unwrap();
        p.add_stage("relu", Op::new(OpKind::Relu), vec![c]).unwrap();
        let nests = lower_pipeline(&p);
        (p, nests)
    }

    #[test]
    fn default_schedule_is_legal() {
        let (p, nests) = two_stage();
        let sched = PipelineSchedule::default_for(
            &p.stages.iter().map(|s| s.shape.len()).collect::<Vec<_>>(),
        );
        check_pipeline(&p, &nests, &sched).unwrap();
    }

    #[test]
    fn bad_order_rejected() {
        let (p, nests) = two_stage();
        let mut sched = PipelineSchedule::default_for(&[4, 4]);
        sched.stages[0].order = vec![0, 0, 1, 2];
        assert!(check_pipeline(&p, &nests, &sched).is_err());
    }

    #[test]
    fn vector_width_needs_extent() {
        let (p, nests) = two_stage();
        let mut sched = PipelineSchedule::default_for(&[4, 4]);
        // innermost dim of conv output (w=32) supports width 8
        sched.stages[0].vector_width = 8;
        check_pipeline(&p, &nests, &sched).unwrap();
        // but reorder so innermost is batch (extent 1) -> illegal
        sched.stages[0].order = vec![1, 2, 3, 0];
        assert!(check_pipeline(&p, &nests, &sched).is_err());
    }

    #[test]
    fn inline_only_pointwise() {
        let (p, nests) = two_stage();
        let mut sched = PipelineSchedule::default_for(&[4, 4]);
        // conv (has reduction) cannot inline
        sched.stages[0].compute = ComputeLoc::Inline;
        assert!(check_pipeline(&p, &nests, &sched).is_err());
        // relu is an output stage -> cannot inline either
        let mut sched = PipelineSchedule::default_for(&[4, 4]);
        sched.stages[1].compute = ComputeLoc::Inline;
        assert!(check_pipeline(&p, &nests, &sched).is_err());
    }

    #[test]
    fn compute_at_requires_consumer_edge() {
        let (p, nests) = two_stage();
        let mut sched = PipelineSchedule::default_for(&[4, 4]);
        sched.stages[0].compute = ComputeLoc::At { consumer: 1, level: 2 };
        check_pipeline(&p, &nests, &sched).unwrap();
        sched.stages[0].compute = ComputeLoc::At { consumer: 0, level: 2 };
        assert!(check_pipeline(&p, &nests, &sched).is_err());
    }

    #[test]
    fn parallel_depth_bounded() {
        let (p, nests) = two_stage();
        let mut sched = PipelineSchedule::default_for(&[4, 4]);
        sched.stages[0].parallel_depth = 3;
        check_pipeline(&p, &nests, &sched).unwrap();
        sched.stages[0].parallel_depth = 9;
        assert!(check_pipeline(&p, &nests, &sched).is_err());
    }
}
