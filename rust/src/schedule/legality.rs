//! Schedule legality rules (the subset of Halide's constraints our IR
//! exposes). The random sampler and beam search only emit schedules that
//! pass [`check_pipeline`]; the simulator asserts it in debug builds.

use crate::analysis::AnalyzedPipeline;
use crate::ir::pipeline::Pipeline;
use crate::lower::LoopNest;
use crate::schedule::primitives::{ComputeLoc, PipelineSchedule, StageSchedule};

/// Validate one stage schedule against its loop nest.
pub fn check_stage(
    nest: &LoopNest,
    sched: &StageSchedule,
    consumers: &[usize],
    all_scheds: &[StageSchedule],
) -> Result<(), String> {
    let rank = nest.spatial.len();
    // order must be a permutation of 0..rank
    if sched.order.len() != rank {
        return Err(format!("order len {} != rank {}", sched.order.len(), rank));
    }
    let mut seen = vec![false; rank];
    for &d in &sched.order {
        if d >= rank || seen[d] {
            return Err(format!("order {:?} is not a permutation", sched.order));
        }
        seen[d] = true;
    }
    if sched.tile.len() != rank {
        return Err(format!("tile len {} != rank {}", sched.tile.len(), rank));
    }
    if sched.tile.iter().any(|&f| f == 0) {
        return Err("zero split factor".into());
    }
    // vectorization: innermost loop only, must be power of two 1/4/8, and
    // requires the innermost extent to cover the vector width
    match sched.vector_width {
        1 | 4 | 8 => {}
        w => return Err(format!("unsupported vector width {w}")),
    }
    if sched.vector_width > 1 {
        let inner = sched
            .innermost_dim()
            .ok_or_else(|| "vectorize on rank-0 stage".to_string())?;
        let extent = if sched.tile[inner] > 1 {
            sched.tile[inner]
        } else {
            nest.spatial[inner]
        };
        if extent < sched.vector_width {
            return Err(format!(
                "vector width {} exceeds innermost extent {}",
                sched.vector_width, extent
            ));
        }
    }
    match sched.unroll {
        1 | 2 | 4 | 8 => {}
        u => return Err(format!("unsupported unroll factor {u}")),
    }
    // parallel depth bounded by loop count
    let n_loops = sched.loop_extents(&nest.spatial).len();
    if sched.parallel_depth > n_loops.min(3) {
        return Err(format!(
            "parallel depth {} exceeds limit (loops={})",
            sched.parallel_depth, n_loops
        ));
    }
    // compute location rules
    match sched.compute {
        ComputeLoc::Root => {}
        ComputeLoc::Inline => {
            // Halide can only inline pure (no-reduction) single-valued funcs
            if !nest.pointwise || !nest.reduction.is_empty() {
                return Err("inline of non-pointwise stage".into());
            }
            if consumers.is_empty() {
                return Err("inline of an output stage".into());
            }
        }
        ComputeLoc::At { consumer, level } => {
            if !consumers.contains(&consumer) {
                return Err(format!("compute_at non-consumer {consumer}"));
            }
            // only legal when the consumer materializes (is not inlined)
            if consumer < all_scheds.len()
                && matches!(all_scheds[consumer].compute, ComputeLoc::Inline)
            {
                return Err("compute_at an inlined consumer".into());
            }
            if level == 0 || level > 3 {
                return Err(format!("compute_at level {level} out of range"));
            }
        }
    }
    Ok(())
}

/// Validate a whole pipeline schedule.
///
/// A thin shim over [`AnalyzedPipeline::check_schedule`] — the analyzer
/// pass owns the rules now; this keeps the historical `Result<(), String>`
/// surface. Accept/reject behavior is pinned equal to the pre-analyzer
/// composition (len check + per-stage [`check_stage`]) by a property test
/// below. Callers validating many schedules against one pipeline should
/// build an [`AnalyzedPipeline`] once and call `check_schedule` directly —
/// that skips the per-call consumer-table reallocation this shim pays.
pub fn check_pipeline(
    p: &Pipeline,
    nests: &[LoopNest],
    sched: &PipelineSchedule,
) -> Result<(), String> {
    AnalyzedPipeline::build(p, nests).check_schedule(sched).map_err(|d| d.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Op, OpAttrs, OpKind};
    use crate::lower::lower_pipeline;

    fn two_stage() -> (Pipeline, Vec<LoopNest>) {
        let mut p = Pipeline::new("t");
        let x = p.add_input(vec![1, 16, 32, 32]);
        let mut attrs = OpAttrs::default();
        attrs.out_channels = 8;
        let c = p.add_stage("conv", Op::with_attrs(OpKind::Conv2d, attrs), vec![x]).unwrap();
        p.add_stage("relu", Op::new(OpKind::Relu), vec![c]).unwrap();
        let nests = lower_pipeline(&p);
        (p, nests)
    }

    #[test]
    fn default_schedule_is_legal() {
        let (p, nests) = two_stage();
        let sched = PipelineSchedule::default_for(
            &p.stages.iter().map(|s| s.shape.len()).collect::<Vec<_>>(),
        );
        check_pipeline(&p, &nests, &sched).unwrap();
    }

    #[test]
    fn bad_order_rejected() {
        let (p, nests) = two_stage();
        let mut sched = PipelineSchedule::default_for(&[4, 4]);
        sched.stages[0].order = vec![0, 0, 1, 2];
        assert!(check_pipeline(&p, &nests, &sched).is_err());
    }

    #[test]
    fn vector_width_needs_extent() {
        let (p, nests) = two_stage();
        let mut sched = PipelineSchedule::default_for(&[4, 4]);
        // innermost dim of conv output (w=32) supports width 8
        sched.stages[0].vector_width = 8;
        check_pipeline(&p, &nests, &sched).unwrap();
        // but reorder so innermost is batch (extent 1) -> illegal
        sched.stages[0].order = vec![1, 2, 3, 0];
        assert!(check_pipeline(&p, &nests, &sched).is_err());
    }

    #[test]
    fn inline_only_pointwise() {
        let (p, nests) = two_stage();
        let mut sched = PipelineSchedule::default_for(&[4, 4]);
        // conv (has reduction) cannot inline
        sched.stages[0].compute = ComputeLoc::Inline;
        assert!(check_pipeline(&p, &nests, &sched).is_err());
        // relu is an output stage -> cannot inline either
        let mut sched = PipelineSchedule::default_for(&[4, 4]);
        sched.stages[1].compute = ComputeLoc::Inline;
        assert!(check_pipeline(&p, &nests, &sched).is_err());
    }

    #[test]
    fn compute_at_requires_consumer_edge() {
        let (p, nests) = two_stage();
        let mut sched = PipelineSchedule::default_for(&[4, 4]);
        sched.stages[0].compute = ComputeLoc::At { consumer: 1, level: 2 };
        check_pipeline(&p, &nests, &sched).unwrap();
        sched.stages[0].compute = ComputeLoc::At { consumer: 0, level: 2 };
        assert!(check_pipeline(&p, &nests, &sched).is_err());
    }

    #[test]
    fn parallel_depth_bounded() {
        let (p, nests) = two_stage();
        let mut sched = PipelineSchedule::default_for(&[4, 4]);
        sched.stages[0].parallel_depth = 3;
        check_pipeline(&p, &nests, &sched).unwrap();
        sched.stages[0].parallel_depth = 9;
        assert!(check_pipeline(&p, &nests, &sched).is_err());
    }

    /// The pre-analyzer implementation of `check_pipeline`, reconstructed
    /// verbatim: the length check plus per-stage [`check_stage`] over the
    /// freshly built consumer table.
    fn legacy_check_pipeline(
        p: &Pipeline,
        nests: &[LoopNest],
        sched: &PipelineSchedule,
    ) -> Result<(), String> {
        if sched.stages.len() != p.num_stages() {
            return Err(format!(
                "schedule covers {} stages, pipeline has {}",
                sched.stages.len(),
                p.num_stages()
            ));
        }
        let consumers = p.consumers();
        for (i, s) in sched.stages.iter().enumerate() {
            check_stage(&nests[i], s, &consumers[i], &sched.stages)
                .map_err(|e| format!("stage {i} ({}): {e}", p.stages[i].op.kind.name()))?;
        }
        Ok(())
    }

    /// Seeded mutation of one stage into one `S0xx` violation class (or a
    /// no-op), covering every class the mutator can reach on this stage.
    fn mutate_into_violation(sched: &mut PipelineSchedule, rng: &mut crate::util::rng::Rng) {
        let sid = rng.gen_range(sched.stages.len());
        let n = sched.stages.len();
        let class = rng.gen_range(10);
        if class == 0 {
            sched.stages.pop(); // S001
            return;
        }
        let target = rng.gen_range(n);
        let s = &mut sched.stages[sid];
        match class {
            1 => s.order = vec![0; s.order.len()], // S002
            2 => s.tile.push(0),                   // S003 (len + zero factor)
            3 => s.vector_width = 3,               // S004
            4 => {
                // S005: vectorize with the (usually extent-1) batch dim inner
                if !s.order.is_empty() {
                    s.order.rotate_left(1);
                }
                s.vector_width = 8;
            }
            5 => s.unroll = 5,                // S006
            6 => s.parallel_depth = 9,        // S007
            7 => s.compute = ComputeLoc::Inline, // S008/S009 depending on stage
            8 => s.compute = ComputeLoc::At { consumer: sid, level: 2 }, // S010 (self)
            _ => s.compute = ComputeLoc::At { consumer: target, level: 9 }, // S010/S012
        }
    }

    #[test]
    fn prop_shim_matches_legacy_accept_reject() {
        use crate::util::propcheck;
        let cases = propcheck::default_cases().min(48);
        propcheck::check_rng("analyzer shim == legacy legality", 0x1E6A1, cases, |rng| {
            let cfg = crate::onnx_gen::GenConfig::default();
            let p = crate::onnx_gen::generate_model(&cfg, rng, 0);
            let nests = lower_pipeline(&p);
            let mut sched = crate::schedule::random::random_pipeline_schedule(&p, &nests, rng);
            if rng.gen_range(4) > 0 {
                mutate_into_violation(&mut sched, rng);
            }
            let new = check_pipeline(&p, &nests, &sched);
            let old = legacy_check_pipeline(&p, &nests, &sched);
            if new.is_ok() != old.is_ok() {
                return Err(format!(
                    "divergence on {}: shim {new:?} vs legacy {old:?}",
                    p.name
                ));
            }
            Ok(())
        });
    }
}
