//! Scheduling: the Halide-style per-stage schedule space (§II-A).
//!
//! A [`StageSchedule`] records the choices made for one stage: loop tiling
//! (split), loop order (reorder), vectorization, parallelization, unrolling
//! and the compute location (`compute_root` / `compute_at` / inline). A
//! [`PipelineSchedule`] is one schedule per stage; [`legal`](legality) checks
//! enforce Halide's constraints, and [`random`] samples the space the way the
//! paper's noisy auto-scheduler explores it.

pub mod primitives;
pub mod legality;
pub mod random;
pub mod space;

pub use primitives::{ComputeLoc, PipelineSchedule, StageSchedule};
