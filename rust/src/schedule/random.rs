//! Random schedule sampling — the stand-in for the paper's noise-injected
//! auto-scheduler (§III-A: "By injecting the performance model with random
//! noise, we can derive multiple schedules for each pipeline").
//!
//! Sampling is biased the way real auto-scheduler output is: vectorization
//! and parallelism are common, deep tilings and exotic reorders are rarer,
//! and cheap pointwise stages are frequently inlined.

use crate::ir::pipeline::Pipeline;
use crate::lower::LoopNest;
use crate::schedule::legality::check_pipeline;
use crate::schedule::primitives::{ComputeLoc, PipelineSchedule, StageSchedule};
use crate::util::rng::Rng;

const SPLIT_FACTORS: &[usize] = &[2, 4, 8, 16, 32, 64];

/// Sample a random legal schedule for one stage.
pub fn random_stage_schedule(
    nest: &LoopNest,
    consumers: &[usize],
    rng: &mut Rng,
) -> StageSchedule {
    let rank = nest.spatial.len();
    let mut s = StageSchedule::default_for(rank);

    // -- compute location
    if !consumers.is_empty() {
        let r = rng.f64();
        if nest.pointwise && nest.reduction.is_empty() && r < 0.35 {
            s.compute = ComputeLoc::Inline;
        } else if r < 0.55 {
            s.compute = ComputeLoc::At {
                consumer: *rng.choice(consumers),
                level: rng.gen_range_incl(1, 3),
            };
        }
    }

    // -- reorder (keep natural order 60% of the time)
    if rank >= 2 && rng.chance(0.4) {
        // swap a random adjacent pair or fully shuffle (rarely)
        if rng.chance(0.25) {
            rng.shuffle(&mut s.order);
        } else {
            let i = rng.gen_range(rank - 1);
            s.order.swap(i, i + 1);
        }
    }

    // -- tiling: split up to 2 dims with a factor <= extent
    let n_splits = rng.categorical(&[0.45, 0.35, 0.20]); // 0,1,2 dims
    for _ in 0..n_splits {
        let d = rng.gen_range(rank);
        let extent = nest.spatial[d];
        let candidates: Vec<usize> =
            SPLIT_FACTORS.iter().copied().filter(|&f| f < extent).collect();
        if !candidates.is_empty() {
            s.tile[d] = *rng.choice(&candidates);
        }
    }

    // -- vectorize the innermost loop when wide enough (very common)
    let inner = s.innermost_dim().unwrap_or(0);
    if rank > 0 {
        let inner_extent = if s.tile[inner] > 1 { s.tile[inner] } else { nest.spatial[inner] };
        if inner_extent >= 8 && rng.chance(0.7) {
            s.vector_width = 8;
        } else if inner_extent >= 4 && rng.chance(0.5) {
            s.vector_width = 4;
        }
    }

    // -- parallelize outer loops (common for big stages)
    if rank > 0 && nest.points() > 4096.0 {
        s.parallel_depth = rng.categorical(&[0.25, 0.55, 0.20]); // 0,1,2
    } else if rank > 0 {
        s.parallel_depth = rng.categorical(&[0.7, 0.3]); // 0,1
    }
    // cap by loop count (legality also checks)
    s.parallel_depth = s.parallel_depth.min(s.loop_extents(&nest.spatial).len().min(3));

    // -- unroll
    if rng.chance(0.2) {
        s.unroll = *rng.choice(&[2usize, 4]);
    }
    s
}

/// Sample a random legal schedule for the whole pipeline.
///
/// Stages are scheduled consumer-first (reverse topological order), the way
/// the Halide auto-scheduler walks the DAG (§II-C.2: "The pipeline is
/// scheduled stage-by-stage, beginning from the last/output stage").
pub fn random_pipeline_schedule(
    p: &Pipeline,
    nests: &[LoopNest],
    rng: &mut Rng,
) -> PipelineSchedule {
    let consumers = p.consumers();
    let mut stages: Vec<StageSchedule> = p
        .stages
        .iter()
        .map(|s| StageSchedule::default_for(s.shape.len()))
        .collect();
    for id in (0..p.num_stages()).rev() {
        stages[id] = random_stage_schedule(&nests[id], &consumers[id], rng);
        // compute_at an inlined consumer is illegal; retarget to Root
        if let ComputeLoc::At { consumer, .. } = stages[id].compute {
            if matches!(stages[consumer].compute, ComputeLoc::Inline) {
                stages[id].compute = ComputeLoc::Root;
            }
        }
    }
    let sched = PipelineSchedule { stages };
    debug_assert!(
        check_pipeline(p, nests, &sched).is_ok(),
        "sampler produced illegal schedule: {:?}",
        check_pipeline(p, nests, &sched)
    );
    sched
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::op::{Op, OpAttrs, OpKind};
    use crate::lower::lower_pipeline;
    use crate::util::propcheck;

    fn sample_pipeline(rng: &mut Rng) -> Pipeline {
        // small random chain: conv -> relu -> pool -> sigmoid
        let mut p = Pipeline::new("chain");
        let h = 8 << rng.gen_range(3); // 8..64
        let x = p.add_input(vec![1, 3, h, h]);
        let mut attrs = OpAttrs::default();
        attrs.out_channels = 4 << rng.gen_range(3);
        let c = p.add_stage("conv", Op::with_attrs(OpKind::Conv2d, attrs), vec![x]).unwrap();
        let r = p.add_stage("relu", Op::new(OpKind::Relu), vec![c]).unwrap();
        let mut pool = OpAttrs::default();
        pool.kernel = (2, 2);
        pool.stride = 2;
        pool.pad = 0;
        let q = p.add_stage("pool", Op::with_attrs(OpKind::MaxPool, pool), vec![r]).unwrap();
        p.add_stage("sig", Op::new(OpKind::Sigmoid), vec![q]).unwrap();
        p
    }

    #[test]
    fn prop_sampled_schedules_always_legal() {
        propcheck::check_rng("random schedules legal", 0xBEEF, propcheck::default_cases(), |rng| {
            let p = sample_pipeline(rng);
            let nests = lower_pipeline(&p);
            for _ in 0..8 {
                let s = random_pipeline_schedule(&p, &nests, rng);
                check_pipeline(&p, &nests, &s).map_err(|e| e.to_string())?;
            }
            Ok(())
        });
    }

    #[test]
    fn sampler_is_deterministic_per_seed() {
        let mut r1 = Rng::new(77);
        let mut r2 = Rng::new(77);
        let p = sample_pipeline(&mut Rng::new(1));
        let nests = lower_pipeline(&p);
        let a = random_pipeline_schedule(&p, &nests, &mut r1);
        let b = random_pipeline_schedule(&p, &nests, &mut r2);
        assert_eq!(a, b);
    }

    #[test]
    fn sampler_produces_diversity() {
        let p = sample_pipeline(&mut Rng::new(2));
        let nests = lower_pipeline(&p);
        let mut rng = Rng::new(3);
        let mut distinct = std::collections::HashSet::new();
        for _ in 0..50 {
            let s = random_pipeline_schedule(&p, &nests, &mut rng);
            distinct.insert(format!("{s:?}"));
        }
        assert!(distinct.len() > 30, "only {} distinct schedules", distinct.len());
    }
}
