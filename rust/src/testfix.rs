//! Shared test fixtures (test builds only): the JAX-pinned parity
//! fixtures used by both GCN engines, plus the synthetic-sample builders
//! the model/runtime test suites share.
//!
//! The fixtures are deterministic integer patterns matching the JAX
//! reference generator (see DESIGN.md §Testing): the dense batch is the
//! exact tensor layout the reference `python/compile/kernels/ref.py`
//! forward consumed, and `REF_Z` / `REF_GRADS` / `REF_LOSS` are the
//! numbers that JAX model produced on it. The sparse engine must
//! reproduce them through `PackedBatch::from_dense` — that conversion
//! plus parity is what makes the sparse rewrite a refactor instead of a
//! fork.

use crate::constants::{BATCH, BENCH_RUNS, DEP_DIM, INV_DIM, MAX_NODES};
use crate::dataset::sample::GraphSample;
use crate::features::normalize::FeatureStats;
use crate::model::{DenseBatch, PackedBatch};
use crate::runtime::manifest::Manifest;
use crate::runtime::params::Params;

/// Deterministic integer-pattern fill shared with the JAX reference
/// generator: `h = (i·mul + add) mod m; v = (h − sub) / div` in f32.
pub fn pat(i: usize, mul: u64, add: u64, m: u64, sub: f32, div: f32) -> f32 {
    let h = ((i as u64) * mul + add) % m;
    (h as f32 - sub) / div
}

/// The parity fixture: patterned features/adjacency, sample `b` has
/// `3 + (7b mod 45)` real stages.
pub fn parity_batch() -> DenseBatch {
    let n = MAX_NODES;
    let mut b = DenseBatch::zeros(BATCH, n, BATCH);
    for (i, v) in b.inv.iter_mut().enumerate() {
        *v = pat(i, 131, 7, 997, 498.0, 997.0);
    }
    for (i, v) in b.dep.iter_mut().enumerate() {
        *v = pat(i, 131, 307, 997, 498.0, 997.0);
    }
    for (i, v) in b.adj.iter_mut().enumerate() {
        *v = pat(i, 89, 3, 512, 0.0, 24576.0);
    }
    for bb in 0..BATCH {
        let real = 3 + (7 * bb) % 45;
        for nn in 0..real {
            b.mask[bb * n + nn] = 1.0;
        }
        b.sample_mask[bb] = 1.0;
    }
    b
}

/// Patterned parameters matching the JAX reference generator.
pub fn parity_params(manifest: &Manifest) -> Params {
    let mut values = Vec::new();
    let mut shapes = Vec::new();
    let mut names = Vec::new();
    for (ti, spec) in manifest.params.iter().enumerate() {
        let v: Vec<f32> = (0..spec.numel())
            .map(|i| {
                let h = ((ti as u64) * 1009 + (i as u64) * 193) % 1013;
                let base = (h as f32 - 506.0) / 1013.0;
                if spec.name == "w_out" {
                    base * 0.05
                } else if spec.name.ends_with("_scale") {
                    1.0 + base * 0.25
                } else {
                    base * 0.25
                }
            })
            .collect();
        values.push(v);
        shapes.push(spec.shape.clone());
        names.push(spec.name.clone());
    }
    Params { values, shapes, names }
}

/// z for the parity fixture, computed by the repo's JAX model with
/// `use_pallas=False` (i.e. through `python/compile/kernels/ref.py`).
pub const REF_Z: [f32; 32] = [
    -2.058540821e0,
    -6.377158165e0,
    -9.944972038e0,
    -1.221917439e1,
    -1.431323147e1,
    -1.581014824e1,
    -1.778214264e1,
    -4.756258011e0,
    -8.321274757e0,
    -1.084673595e1,
    -1.295297146e1,
    -1.504773235e1,
    -1.781664848e1,
    -2.804502487e0,
    -7.006120682e0,
    -9.869874001e0,
    -1.217363834e1,
    -1.442363739e1,
    -1.650897217e1,
    -1.865101242e1,
    -5.215301991e0,
    -8.816872597e0,
    -1.120118141e1,
    -1.382463169e1,
    -1.543310452e1,
    -1.775400925e1,
    -3.412985563e0,
    -7.477596760e0,
    -1.036118412e1,
    -1.242816830e1,
    -1.427667713e1,
    -1.616724014e1,
];

/// Targets for the gradient parity test (the same fixture + these labels).
pub fn grad_fixture_batch() -> DenseBatch {
    let mut b = parity_batch();
    for i in 0..BATCH {
        b.log_y[i] = -11.0 + (((i * 5) % 13) as f32) * 1.3;
        b.weight[i] = 0.4 + (((i * 7) % 9) as f32) * 0.11;
        b.sample_mask[i] = if i >= 30 { 0.0 } else { 1.0 };
    }
    b
}

/// Selected `jax.grad(model.loss_fn)` entries for the gradient fixture:
/// (tensor index, element index, reference value).
pub const REF_GRADS: [(usize, usize, f64); 13] = [
    (0, 100, -7.715898752e-2),  // w_inv
    (1, 3, 6.745553493e0),      // b_inv
    (2, 500, -2.495915815e-2),  // w_dep
    (3, 17, 5.561747551e0),     // b_dep
    (4, 321, 1.312017292e-1),   // conv0_w
    (5, 44, -1.284459591e0),    // conv0_b
    (6, 10, -5.948795319e1),    // conv0_scale
    (7, 77, -1.478031921e1),    // conv0_shift
    (8, 1234, -3.098664856e1),  // conv1_w
    (10, 63, 2.591241002e-1),   // conv1_scale
    (12, 100, -5.401177979e2),  // w_out
    (12, 239, 0.0),             // w_out — ReLU-dead readout channel
    (13, 0, -1.414331627e1),    // b_out
];

pub const REF_LOSS: f64 = 1.421302185e2;

/// A chain-topology sample with an explicit stage count — the minimal
/// fixture for batching/layout tests.
pub fn chain_sample(n_stages: u32, runtime: f32) -> GraphSample {
    let ns = n_stages as usize;
    GraphSample {
        pipeline_id: 1,
        schedule_id: 0,
        n_stages,
        edges: (0..ns.saturating_sub(1))
            .map(|i| (i as u32, (i + 1) as u32))
            .collect(),
        inv: vec![[0.5; INV_DIM]; ns],
        dep: vec![[1.5; DEP_DIM]; ns],
        runs: [runtime; BENCH_RUNS],
    }
}

/// Deterministic synthetic sample shared by the training/inference tests.
pub fn synth_sample(pid: u32, sid: u32, runtime: f32) -> GraphSample {
    let ns = (4 + (pid as usize + sid as usize) % 5) as u32;
    let n = ns as usize;
    let mut inv = vec![[0f32; INV_DIM]; n];
    let mut dep = vec![[0f32; DEP_DIM]; n];
    for s in 0..n {
        for j in 0..INV_DIM {
            inv[s][j] = pat(
                (pid as usize * 97 + s) * INV_DIM + j,
                211,
                5,
                883,
                441.0,
                441.0,
            );
        }
        for j in 0..DEP_DIM {
            dep[s][j] = pat(
                ((pid as usize * 31 + sid as usize * 7 + s) * DEP_DIM) + j,
                157,
                11,
                883,
                441.0,
                441.0,
            );
        }
    }
    GraphSample {
        pipeline_id: pid,
        schedule_id: sid,
        n_stages: ns,
        edges: (0..n.saturating_sub(1)).map(|i| (i as u32, (i + 1) as u32)).collect(),
        inv,
        dep,
        runs: [runtime; BENCH_RUNS],
    }
}

pub fn identity_stats() -> FeatureStats {
    FeatureStats {
        inv_mean: vec![0.0; INV_DIM],
        inv_std: vec![1.0; INV_DIM],
        dep_mean: vec![0.0; DEP_DIM],
        dep_std: vec![1.0; DEP_DIM],
    }
}

/// Fixed-seed synthetic batch: 4 pipelines × 8 schedules with runtimes
/// spread ~6×, plus the per-pipeline best for the α weights.
pub fn synth_packed_batch() -> PackedBatch {
    let mut samples = Vec::new();
    let mut best = Vec::new();
    for i in 0..BATCH {
        let pid = (i / 8) as u32;
        let sid = (i % 8) as u32;
        let base = 1e-3 * (1.0 + pid as f32);
        samples.push(synth_sample(pid, sid, base * (1.0 + 0.7 * sid as f32)));
        best.push(base as f64);
    }
    let refs: Vec<&GraphSample> = samples.iter().collect();
    PackedBatch::build(&refs, &identity_stats(), &best).unwrap()
}
