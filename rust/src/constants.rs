//! Dimensions shared between the rust featurizer and the JAX model.
//!
//! These MUST agree with `python/compile/dims.py`; `runtime::manifest`
//! cross-checks them against `artifacts/manifest.json` at load time.

/// Schedule-invariant feature vector length (per stage). §II-C.1.
pub const INV_DIM: usize = 48;
/// Schedule-dependent (66) + compound (22) feature vector length. §II-C.2.
pub const DEP_DIM: usize = 88;
/// Embedding width of the invariant features (Fig 5).
pub const EMB_INV: usize = 32;
/// Embedding width of the dependent features (Fig 5).
pub const EMB_DEP: usize = 48;
/// Node embedding width = EMB_INV + EMB_DEP.
pub const NODE_DIM: usize = 80;
/// Graph-convolution hidden width (all conv layers share it).
pub const HIDDEN: usize = 80;
/// Number of graph convolution layers (paper sweeps 0–8, picks 2).
pub const N_CONV: usize = 2;
/// Readout width: initial + one per conv layer, summed over stages (Fig 7).
pub const READOUT: usize = NODE_DIM * (N_CONV + 1);
/// Maximum number of stages per pipeline; graphs are padded to this.
pub const MAX_NODES: usize = 48;
/// Training / inference batch size baked into the AOT artifacts.
pub const BATCH: usize = 32;
/// Benchmark repetitions per schedule (paper: N = 10).
pub const BENCH_RUNS: usize = 10;

/// Number of hand-crafted terms in the Halide FFN baseline head (Fig 3).
pub const FFN_TERMS: usize = 27;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_dims_consistent() {
        assert_eq!(NODE_DIM, EMB_INV + EMB_DEP);
        assert_eq!(READOUT, NODE_DIM * (N_CONV + 1));
        assert!(MAX_NODES >= 5, "generator depth filter needs >=5 stages");
    }
}
