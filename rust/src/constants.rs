//! Dimensions shared between the rust featurizer and the GCN model.
//!
//! These MUST agree with `python/compile/dims.py`; `runtime::manifest`
//! cross-checks them against `artifacts/manifest.json` at load time, and
//! `runtime::native` builds its in-memory manifest directly from them.
//!
//! The native engine runs on the sparse packed layout
//! (`model::PackedBatch`) with no graph-size or batch-size caps;
//! `MAX_NODES` and `BATCH` survive as (a) the fixed tensor shapes of the
//! AOT artifacts on the `pjrt` path and (b) the default graphs-per-batch
//! chunking policy. Artifact tensor shapes (see `python/compile/aot.py`):
//!
//! * `inv`:  `[BATCH, MAX_NODES, INV_DIM]` — normalized schedule-invariant
//!   stage features;
//! * `dep`:  `[BATCH, MAX_NODES, DEP_DIM]` — normalized schedule-dependent
//!   (+compound) stage features;
//! * `adj`:  `[BATCH, MAX_NODES, MAX_NODES]` — row-normalized adjacency
//!   with self loops (A′);
//! * `mask`: `[BATCH, MAX_NODES]` — 1.0 for real stages, 0.0 for padding;
//! * output `z`: `[BATCH]` — predicted log-runtime per graph.

/// Schedule-invariant feature vector length (per stage). §II-C.1.
pub const INV_DIM: usize = 48;
/// Schedule-dependent (66) + compound (22) feature vector length. §II-C.2.
pub const DEP_DIM: usize = 88;
/// Embedding width of the invariant features (Fig 5).
pub const EMB_INV: usize = 32;
/// Embedding width of the dependent features (Fig 5).
pub const EMB_DEP: usize = 48;
/// Node embedding width = EMB_INV + EMB_DEP.
pub const NODE_DIM: usize = 80;
/// Graph-convolution hidden width (all conv layers share it).
pub const HIDDEN: usize = 80;
/// Number of graph convolution layers (paper sweeps 0–8, picks 2).
pub const N_CONV: usize = 2;
/// Readout width: initial + one per conv layer, summed over stages (Fig 7).
pub const READOUT: usize = NODE_DIM * (N_CONV + 1);
/// Padded node count of the dense layout — a cap only on the `pjrt`
/// artifact path; the sparse packed layout has no stage limit.
pub const MAX_NODES: usize = 48;
/// Graphs per training/inference batch: the chunking policy of the
/// packed layout, and the fixed batch dim of the AOT artifacts.
pub const BATCH: usize = 32;
/// Benchmark repetitions per schedule (paper: N = 10).
pub const BENCH_RUNS: usize = 10;

/// Default per-batch node budget for training and prediction batching.
/// Graphs accumulate into one packed batch until either [`BATCH`] graphs
/// or this many packed nodes are reached, whichever comes first — so a
/// batch of zoo-scale graphs behaves exactly as before (32 × ≤59 stages
/// ≈ 1.9k nodes, far under budget) while TpuGraphs-scale graphs cannot
/// blow the workspace. A single graph above the budget trains through
/// the partition-sampled path (`model::partition`).
pub const DEFAULT_NODE_BUDGET: usize = 8192;

/// Node granularity of graph partitions — identical to the backward
/// pass's fixed `BACKWARD_BLOCK_NODES` blocking so a partition boundary
/// is always a backward-block boundary.
pub const PARTITION_BLOCK_NODES: usize = 512;

/// The effective node budget: [`DEFAULT_NODE_BUDGET`] unless the
/// `GCN_PERF_NODE_BUDGET` environment variable overrides it (clamped to
/// at least one partition block).
pub fn node_budget() -> usize {
    std::env::var("GCN_PERF_NODE_BUDGET")
        .ok()
        .and_then(|v| v.parse::<usize>().ok())
        .map(|v| v.max(PARTITION_BLOCK_NODES))
        .unwrap_or(DEFAULT_NODE_BUDGET)
}

/// Number of hand-crafted terms in the Halide FFN baseline head (Fig 3).
pub const FFN_TERMS: usize = 27;

/// Adagrad learning rate (§III-C; `dims.LEARNING_RATE`).
pub const LEARNING_RATE: f64 = 0.0075;
/// Weight decay added to the gradients before the Adagrad step (§III-C).
pub const WEIGHT_DECAY: f64 = 1e-4;
/// Adagrad denominator epsilon (`dims.ADAGRAD_EPS`).
pub const ADAGRAD_EPS: f64 = 1e-10;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn derived_dims_consistent() {
        assert_eq!(NODE_DIM, EMB_INV + EMB_DEP);
        assert_eq!(READOUT, NODE_DIM * (N_CONV + 1));
        assert!(MAX_NODES >= 5, "generator depth filter needs >=5 stages");
    }

    #[test]
    fn node_budget_defaults_and_clamps() {
        // the default keeps every zoo-scale batch unsplit
        assert!(DEFAULT_NODE_BUDGET >= BATCH * MAX_NODES);
        assert_eq!(DEFAULT_NODE_BUDGET % PARTITION_BLOCK_NODES, 0);
        // without the env override the default is in force (the test
        // harness never sets GCN_PERF_NODE_BUDGET)
        if std::env::var("GCN_PERF_NODE_BUDGET").is_err() {
            assert_eq!(node_budget(), DEFAULT_NODE_BUDGET);
        }
    }
}
