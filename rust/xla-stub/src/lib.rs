//! API stub of the `xla` (xla-rs) PJRT binding.
//!
//! This crate exists so `cargo check --features pjrt` typechecks the PJRT
//! backend (`runtime::gcn`) in environments without the real XLA runtime:
//! every constructor returns an error at runtime, and the higher-level
//! backend loader falls back to the pure-Rust native backend. To execute
//! the AOT HLO artifacts for real, point the `xla` dependency in
//! `Cargo.toml` at an actual xla-rs checkout — the surface here mirrors
//! the subset of its API that `runtime::gcn` uses.

use std::fmt;

/// Error type mirroring `xla::Error`: a plain message, usable with `?`
/// under `anyhow`.
#[derive(Debug)]
pub struct Error(pub String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "xla stub: {}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>() -> Result<T> {
    Err(Error(
        "PJRT runtime not available in this build (offline xla stub; \
         link a real xla-rs binding to execute HLO artifacts)"
            .to_string(),
    ))
}

/// Parsed HLO module (stub).
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO text file (as emitted by `python/compile/aot.py`).
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable()
    }
}

/// An XLA computation ready for compilation (stub).
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A PJRT client (stub of the CPU client).
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Construct the CPU PJRT client. Always fails in the stub.
    pub fn cpu() -> Result<PjRtClient> {
        unavailable()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable()
    }

    /// Upload a host buffer with the given dimensions to the device.
    pub fn buffer_from_host_buffer(
        &self,
        _data: &[f32],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable()
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }
}

/// A device buffer (stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable()
    }
}

/// A compiled executable (stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with buffer arguments; returns per-device output buffers.
    pub fn execute_b<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable()
    }
}

/// A host-side literal value (stub).
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Unwrap a 1-tuple literal.
    pub fn to_tuple1(&self) -> Result<Literal> {
        unavailable()
    }

    /// Unwrap a tuple literal into its elements.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        unavailable()
    }

    /// Copy out as a typed vector.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable()
    }
}
